//! The `repro serve` wire protocol: newline-delimited JSON over TCP.
//!
//! ## Requests (client -> server, one JSON object per line)
//!
//! ```json
//! {"id":"r1","prompt":[5,17,3],"max_new":32}
//! {"id":"r2","prompt":[5],"max_new":16,"temperature":0.8,"top_k":40,"top_p":0.95,"seed":7}
//! {"id":"r3","prompt":[5],"max_new":16,"stop":0}
//! {"id":"r4","prompt":[5],"max_new":16,"adapter":"taskA"}
//! {"id":"r5","prompt":[5,9],"max_new":16,"session":"alice"}
//! {"cmd":"stats"}
//! {"cmd":"metrics"}
//! {"cmd":"trace","n":32}
//! {"cmd":"adapter","op":"load","name":"taskA","path":"checkpoints/adapter_taskA.apq"}
//! {"cmd":"adapter","op":"unload","name":"taskA"}
//! {"cmd":"drain"}
//! {"cmd":"shutdown"}
//! ```
//!
//! `id` is any client-chosen string echoed in every frame; `prompt` is a
//! token-id array; `max_new` defaults to 32 and must not exceed the
//! server's `--max-new-cap` (over-cap requests get a `bad_request` error
//! frame instead of a silent clamp).  Omitting `temperature` (or
//! setting it `<= 0`) selects greedy decoding; otherwise temperature /
//! top-k / top-p / seed configure the seeded sampler.  `stop` ends the
//! stream early when that token is produced.  `"adapter"` routes the
//! request through a named registry adapter (unknown names get an error
//! frame); omitted = the model's default path.  `"deadline_ms"` gives
//! the request a wall-clock budget measured from submission: a request
//! that cannot be admitted before the budget expires is rejected with a
//! `deadline` error frame, and a running request that outlives it
//! finishes early with `"finish":"deadline"` (its KV pages are released
//! like any other finish).  The server's `--deadline-ms` supplies a
//! default for requests that omit the field; `0` (the default) means no
//! deadline.  `"session"` names a resumable session on a tiered server
//! (`--kv-spill`): when the connection drops mid-stream, the sequence's
//! KV pages are parked verbatim on the spill file under that name
//! instead of being recycled, and a later request carrying the same
//! `session` whose prompt extends the parked token history resumes
//! decoding from the stored pages with no re-prefill (the `done` frame's
//! `shared_prefix_tokens` counts the restored positions).  Session names
//! are client-chosen and trusted (no auth); without `--kv-spill` the
//! field is accepted and ignored.  `{"cmd":"stats"}` asks the engine
//! for a one-off stats frame (KV memory + queue state).
//! `{"cmd":"adapter",...}` loads an
//! APIQADPT sidecar into (or unloads it from) the engine's registry at
//! runtime; an unload with sequences in flight answers
//! `"status":"draining"` and completes when they finish.
//! `{"cmd":"metrics"}` returns the full telemetry registry as one JSON
//! frame (the same data `--metrics-addr` exposes as Prometheus text);
//! `{"cmd":"trace","n":K}` returns the last `K` scheduler-tick trace
//! records from the in-memory ring (`n` defaults to 16, capped at 4096).
//! `{"cmd":"drain"}` puts the engine into drain mode: new requests are
//! refused with an `unavailable` error frame, in-flight sequences run to
//! completion, the trace journal and final stats flush, and the process
//! exits 0.  SIGINT/SIGTERM trigger the same drain sequence.
//!
//! ## Frames (server -> client, one JSON object per line)
//!
//! ```json
//! {"id":"r1","event":"token","index":0,"token":42}
//! {"id":"r1","event":"done","finish":"length","prompt_len":3,"tokens":[42,7],
//!  "stats":{"queue_ms":0.1,"prefill_ms":3.2,"total_ms":40.5,"tokens_per_sec":790.1,
//!           "max_gap_ms":2.0,"shared_prefix_tokens":0,
//!           "spec_proposed":16,"spec_accepted":13}}
//! {"id":"r1","event":"error","code":"bad_request","message":"..."}
//! {"id":"r9","event":"error","code":"overloaded","retry_after_ms":50,"message":"..."}
//! {"id":"","event":"drain","status":"draining","in_flight":3}
//! {"id":"","event":"adapter","op":"load","name":"taskA","status":"loaded"}
//! {"id":"","event":"stats","active":1,"pending":0,"completed":7,
//!  "uptime_secs":12.5,
//!  "build":{"version":"0.1.0","kernel":"avx2","threads":8,"features":[]},
//!  "kv":{"block_size":32,"blocks_total":384,"resident_blocks":12,"free_blocks":4,
//!        "used_blocks":8,"shared_blocks":2,"peak_resident_blocks":12,
//!        "peak_shared_blocks":3,"block_bytes":65536,"resident_bytes":786432,
//!        "peak_resident_bytes":786432},
//!  "spec":{"k":4,"proposed":480,"accepted":401,"acceptance":0.835,
//!          "cycles":120,"fallbacks":0,"draft_kv":{...same fields as kv...}},
//!  "tier":{"spilled_blocks":12,"spilled_bytes":786432,"slots_resident":16,
//!          "slots_total":0,"spill_writes":40,"spill_reads":28,
//!          "preemptions":3,"resumes":3,"suspended":0,
//!          "block_restores":28,"restore_failures":0,
//!          "sessions_stored":1,"session_resumes":2,
//!          "prefix_pages":4,"prefix_hits":5,"prefix_misses":2,
//!          "promotes":5,"promote_ms_total":1.8},
//!  "baseline_tokens":120,
//!  "adapters":[{"name":"taskA","rank":4,"n_adapted":28,"resident_bytes":917504,
//!               "refs":1,"tokens":64,"draining":false,"delta_overhead":0.021}]}
//! ```
//!
//! Tokens stream as they are produced (`index` counts generated tokens
//! from 0; a speculating engine may emit several per scheduler tick);
//! `done.tokens` holds only the generated suffix.  Multiple requests may
//! be in flight on one connection; frames interleave and are routed by
//! `id`.  Stats frames report the paged KV pool — resident / free /
//! used / shared block counts plus high-water marks — and, when the
//! server runs with `--speculate`, a `spec` object with pool-wide
//! proposal/acceptance counters and the draft model's own KV pool, so a
//! client can observe prefix sharing, peak KV memory, and speculative
//! acceptance even after its requests finished.  A server started with
//! `--kv-spill` adds a `tier` object: spill-file occupancy
//! (`spilled_blocks` / `spilled_bytes` live now, `slots_resident` slots
//! ever created against a `slots_total` budget, 0 = unbounded) and raw
//! slot I/O counters, the preempt-to-spill loop (`preemptions`,
//! `resumes`, `suspended` right now), page restores and CRC/I/O
//! `restore_failures`, parked sessions (`sessions_stored` now,
//! `session_resumes` served), and the persistent prefix store
//! (`prefix_pages` published, `prefix_hits` / `prefix_misses` per
//! admission, `promotes` disk->pool page-run promotions with their
//! cumulative `promote_ms_total` wall-clock).
//!
//! ## Error codes
//!
//! Every error frame carries a machine-readable `code` next to the
//! human-readable `message`:
//!
//! * `bad_request` — the line failed to parse or validate (bad JSON,
//!   over-long line, missing fields, `max_new` over the server cap,
//!   prompt too long or empty, token id out of range).
//! * `overloaded` — the submission queue is full; the frame carries a
//!   `retry_after_ms` hint and the request was NOT enqueued.  Clients
//!   should back off and resubmit.
//! * `deadline` — the request's `deadline_ms` budget expired before the
//!   request could be admitted (running requests that hit their deadline
//!   get a normal `done` frame with `"finish":"deadline"` instead).
//! * `unavailable` — the engine is draining or has stopped; the request
//!   was not accepted and will not be.
//! * `internal` — the engine hit an unexpected failure (e.g. a panic
//!   quarantined this sequence); the sequence is terminated and its
//!   pages reclaimed, but the server keeps serving other traffic.

use crate::error::{Error, Result};
use crate::obs::registry::MetricValue;
use crate::obs::{BuildInfo, Telemetry, TickRecord};
use crate::serve::adapters::AdapterStat;
use crate::serve::block::KvStats;
use crate::serve::json::Json;
use crate::serve::sampling::SamplingParams;
use crate::serve::scheduler::{RequestStats, StepEvent};
use crate::serve::spec::SpecStats;
use crate::serve::tier::TierStats;

/// Default `max_new` when a request omits it.
pub const DEFAULT_MAX_NEW: usize = 32;

/// Machine-readable `code` values for error frames (taxonomy in the
/// module docs).
pub mod code {
    pub const BAD_REQUEST: &str = "bad_request";
    pub const OVERLOADED: &str = "overloaded";
    pub const DEADLINE: &str = "deadline";
    pub const UNAVAILABLE: &str = "unavailable";
    pub const INTERNAL: &str = "internal";
}

/// A parsed request line, before engine admission.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    pub id: String,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    pub sampling: Option<SamplingParams>,
    pub stop: Option<i32>,
    /// Route through a named registry adapter; `None` = default path.
    pub adapter: Option<String>,
    /// Wall-clock budget from submission, in ms; `None` = server default.
    pub deadline_ms: Option<u64>,
    /// Resumable-session name for tiered servers; `None` = anonymous.
    pub session: Option<String>,
}

/// Registry operation requested over the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdapterOp {
    Load,
    Unload,
}

impl AdapterOp {
    pub fn as_str(&self) -> &'static str {
        match self {
            AdapterOp::Load => "load",
            AdapterOp::Unload => "unload",
        }
    }
}

/// Default / maximum `n` for `{"cmd":"trace"}`.
pub const DEFAULT_TRACE_N: usize = 16;
pub const MAX_TRACE_N: usize = 4096;

/// One line of client input.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientLine {
    Request(WireRequest),
    Stats,
    /// Full telemetry-registry snapshot as one JSON frame.
    Metrics,
    /// Last `n` scheduler-tick trace records.
    Trace { n: usize },
    /// Runtime registry change: `path` is required for `Load`.
    Adapter { op: AdapterOp, name: String, path: Option<String> },
    /// Stop admitting, finish in-flight work, flush telemetry, exit 0.
    Drain,
    Shutdown,
}

/// Parse one request line.
pub fn parse_line(line: &str) -> Result<ClientLine> {
    let j = Json::parse(line)?;
    if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Ok(ClientLine::Stats),
            "metrics" => Ok(ClientLine::Metrics),
            "trace" => {
                let n = j
                    .get("n")
                    .and_then(Json::as_i64)
                    .map(|v| v.clamp(1, MAX_TRACE_N as i64) as usize)
                    .unwrap_or(DEFAULT_TRACE_N);
                Ok(ClientLine::Trace { n })
            }
            "drain" => Ok(ClientLine::Drain),
            "shutdown" => Ok(ClientLine::Shutdown),
            "adapter" => {
                let op = match j.get("op").and_then(Json::as_str) {
                    Some("load") => AdapterOp::Load,
                    Some("unload") => AdapterOp::Unload,
                    Some(other) => {
                        return Err(Error::config(format!("unknown adapter op '{other}'")))
                    }
                    None => {
                        return Err(Error::config("adapter cmd needs 'op':\"load\"|\"unload\""))
                    }
                };
                let name = j
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| Error::config("adapter cmd needs a string 'name'"))?
                    .to_string();
                let path = j.get("path").and_then(Json::as_str).map(str::to_string);
                if op == AdapterOp::Load && path.is_none() {
                    return Err(Error::config("adapter load needs a string 'path'"));
                }
                Ok(ClientLine::Adapter { op, name, path })
            }
            other => Err(Error::config(format!("unknown cmd '{other}'"))),
        };
    }
    let id = j
        .get("id")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::config("request needs a string 'id'"))?
        .to_string();
    let prompt_json = j
        .get("prompt")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::config("request needs 'prompt': [token, ...]"))?;
    let mut prompt = Vec::with_capacity(prompt_json.len());
    for v in prompt_json {
        let tok = v
            .as_i64()
            .ok_or_else(|| Error::config("prompt tokens must be integers"))?;
        prompt.push(to_token(tok)?);
    }
    let max_new = j
        .get("max_new")
        .and_then(Json::as_i64)
        .map(|v| v.max(1) as usize)
        .unwrap_or(DEFAULT_MAX_NEW);
    let temperature = j.get("temperature").and_then(Json::as_f64).unwrap_or(0.0) as f32;
    let sampling = if temperature > 0.0 {
        Some(SamplingParams {
            temperature,
            top_k: j.get("top_k").and_then(Json::as_i64).map(|v| v.max(0) as usize).unwrap_or(0),
            top_p: j.get("top_p").and_then(Json::as_f64).unwrap_or(1.0) as f32,
            seed: j.get("seed").and_then(Json::as_i64).unwrap_or(17).max(0) as u64,
        })
    } else {
        None
    };
    let stop = match j.get("stop").and_then(Json::as_i64) {
        Some(v) => Some(to_token(v)?),
        None => None,
    };
    let adapter = match j.get("adapter") {
        None => None,
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| Error::config("'adapter' must be a string name"))?
                .to_string(),
        ),
    };
    let session = match j.get("session") {
        None => None,
        Some(v) => {
            let s = v
                .as_str()
                .filter(|s| !s.is_empty())
                .ok_or_else(|| Error::config("'session' must be a non-empty string"))?;
            Some(s.to_string())
        }
    };
    let deadline_ms = match j.get("deadline_ms") {
        None => None,
        Some(v) => {
            let ms = v
                .as_i64()
                .filter(|v| *v > 0)
                .ok_or_else(|| Error::config("'deadline_ms' must be a positive integer"))?;
            Some(ms as u64)
        }
    };
    Ok(ClientLine::Request(WireRequest {
        id,
        prompt,
        max_new,
        sampling,
        stop,
        adapter,
        deadline_ms,
        session,
    }))
}

/// Token ids must fit i32; reject instead of silently wrapping.
fn to_token(v: i64) -> Result<i32> {
    i32::try_from(v).map_err(|_| Error::config(format!("token id {v} out of i32 range")))
}

fn ms(secs: f64) -> Json {
    Json::Num((secs * 1e3 * 1000.0).round() / 1000.0) // ms with us resolution
}

fn stats_json(s: &RequestStats) -> Json {
    Json::Obj(vec![
        ("queue_ms".to_string(), ms(s.queue_secs)),
        ("prefill_ms".to_string(), ms(s.prefill_secs)),
        ("total_ms".to_string(), ms(s.total_secs)),
        ("max_gap_ms".to_string(), ms(s.max_inter_token_secs)),
        (
            "tokens_per_sec".to_string(),
            Json::Num((s.tokens_per_sec() * 10.0).round() / 10.0),
        ),
        ("shared_prefix_tokens".to_string(), Json::from(s.shared_prefix_tokens)),
        ("spec_proposed".to_string(), Json::from(s.spec_proposed)),
        ("spec_accepted".to_string(), Json::from(s.spec_accepted)),
    ])
}

/// The KV pool accounting sub-object shared by the target (`"kv"`) and
/// draft (`"spec.draft_kv"`) pools.
fn kv_json(kv: &KvStats) -> Json {
    Json::Obj(vec![
        ("block_size".to_string(), Json::from(kv.block_size)),
        ("blocks_total".to_string(), Json::from(kv.blocks_total)),
        ("resident_blocks".to_string(), Json::from(kv.resident_blocks)),
        ("free_blocks".to_string(), Json::from(kv.free_blocks)),
        ("used_blocks".to_string(), Json::from(kv.used_blocks)),
        ("shared_blocks".to_string(), Json::from(kv.shared_blocks)),
        ("peak_resident_blocks".to_string(), Json::from(kv.peak_resident_blocks)),
        ("peak_shared_blocks".to_string(), Json::from(kv.peak_shared_blocks)),
        ("block_bytes".to_string(), Json::from(kv.block_bytes)),
        ("resident_bytes".to_string(), Json::from(kv.resident_bytes)),
        ("peak_resident_bytes".to_string(), Json::from(kv.peak_resident_bytes)),
        ("kv_bits".to_string(), Json::from(kv.kv_bits as usize)),
        ("f32_block_bytes".to_string(), Json::from(kv.f32_block_bytes)),
        // Resident bytes as a fraction of what the same resident pages
        // would cost at f32; 1.0 under the f32 layout, ~0.27 sealed 8-bit.
        ("resident_ratio".to_string(), {
            let f32_cost = kv.resident_blocks * kv.f32_block_bytes;
            let r = if f32_cost == 0 {
                1.0
            } else {
                kv.resident_bytes as f64 / f32_cost as f64
            };
            Json::Num((r * 1e4).round() / 1e4)
        }),
    ])
}

/// Everything the `stats` frame renders, gathered by the engine thread.
/// One struct instead of a parade of arguments so exposition sites can't
/// transpose queue counters.
pub struct EngineSnapshot<'a> {
    pub kv: &'a KvStats,
    pub active: usize,
    pub pending: usize,
    pub completed: usize,
    pub spec: Option<&'a SpecStats>,
    pub tier: Option<&'a TierStats>,
    pub adapters: &'a [AdapterStat],
    pub baseline_tokens: u64,
    pub build: &'a BuildInfo,
    pub uptime_secs: f64,
}

/// Render the engine-wide stats frame: queue/batch counters plus the
/// paged KV pool's block accounting (current and high-water), — when
/// the engine speculates — the draft/verify counters and draft KV pool,
/// the adapter registry (per-adapter refs/tokens/overhead plus the
/// default path's `baseline_tokens`), and the process build identity +
/// uptime.
pub fn stats_frame(snap: &EngineSnapshot<'_>) -> String {
    let mut fields = vec![
        ("id".to_string(), Json::from("")),
        ("event".to_string(), Json::from("stats")),
        ("active".to_string(), Json::from(snap.active)),
        ("pending".to_string(), Json::from(snap.pending)),
        ("completed".to_string(), Json::from(snap.completed)),
        ("uptime_secs".to_string(), Json::Num((snap.uptime_secs * 1e3).round() / 1e3)),
        ("build".to_string(), build_json(snap.build)),
        ("kv".to_string(), kv_json(snap.kv)),
    ];
    if let Some(s) = snap.spec {
        fields.push((
            "spec".to_string(),
            Json::Obj(vec![
                ("k".to_string(), Json::from(s.k)),
                ("proposed".to_string(), Json::from(s.proposed)),
                ("accepted".to_string(), Json::from(s.accepted)),
                (
                    "acceptance".to_string(),
                    Json::Num((s.acceptance() * 1000.0).round() / 1000.0),
                ),
                ("cycles".to_string(), Json::from(s.cycles)),
                ("fallbacks".to_string(), Json::from(s.fallbacks)),
                ("draft_kv".to_string(), kv_json(&s.draft_kv)),
            ]),
        ));
    }
    if let Some(t) = snap.tier {
        fields.push(("tier".to_string(), tier_json(t)));
    }
    fields.push(("baseline_tokens".to_string(), Json::from(snap.baseline_tokens as i64)));
    fields.push((
        "adapters".to_string(),
        Json::Arr(snap.adapters.iter().map(adapter_json).collect()),
    ));
    Json::Obj(fields).render()
}

/// The `"tier"` stats sub-object: spill-file occupancy, preempt /
/// resume / restore counters, parked sessions, and the prefix store.
fn tier_json(t: &TierStats) -> Json {
    Json::Obj(vec![
        ("spilled_blocks".to_string(), Json::from(t.spilled_blocks)),
        ("spilled_bytes".to_string(), Json::from(t.spilled_bytes as i64)),
        ("slots_resident".to_string(), Json::from(t.slots_resident)),
        ("slots_total".to_string(), Json::from(t.slots_total)),
        ("spill_writes".to_string(), Json::from(t.spill_writes as i64)),
        ("spill_reads".to_string(), Json::from(t.spill_reads as i64)),
        ("preemptions".to_string(), Json::from(t.preemptions as i64)),
        ("resumes".to_string(), Json::from(t.resumes as i64)),
        ("suspended".to_string(), Json::from(t.suspended)),
        ("block_restores".to_string(), Json::from(t.block_restores as i64)),
        ("restore_failures".to_string(), Json::from(t.restore_failures as i64)),
        ("sessions_stored".to_string(), Json::from(t.sessions_stored)),
        ("session_resumes".to_string(), Json::from(t.session_resumes as i64)),
        ("prefix_pages".to_string(), Json::from(t.prefix_pages)),
        ("prefix_hits".to_string(), Json::from(t.prefix_hits as i64)),
        ("prefix_misses".to_string(), Json::from(t.prefix_misses as i64)),
        ("promotes".to_string(), Json::from(t.promotes as i64)),
        ("promote_ms_total".to_string(), ms(t.promote_secs_total)),
    ])
}

fn build_json(b: &BuildInfo) -> Json {
    Json::Obj(vec![
        ("version".to_string(), Json::from(b.version)),
        ("kernel".to_string(), Json::from(b.kernel)),
        ("threads".to_string(), Json::from(b.threads)),
        (
            "features".to_string(),
            Json::Arr(b.features.iter().map(|f| Json::from(*f)).collect()),
        ),
    ])
}

/// Render the `{"cmd":"metrics"}` response: every registered metric (in
/// registration order, histograms with per-`le` bucket counts — the
/// overflow bucket's bound renders as `null` via the non-finite rule),
/// plus the kernel profiling accumulators and pool-lane busy nanos.
pub fn metrics_frame(obs: &Telemetry) -> String {
    let metrics: Vec<Json> = obs
        .registry
        .snapshot()
        .into_iter()
        .map(|s| {
            let mut fields = vec![("name".to_string(), Json::from(s.name.as_str()))];
            if !s.labels.is_empty() {
                fields.push((
                    "labels".to_string(),
                    Json::Obj(
                        s.labels
                            .iter()
                            .map(|(k, v)| (k.clone(), Json::from(v.as_str())))
                            .collect(),
                    ),
                ));
            }
            match s.value {
                MetricValue::Counter(v) => {
                    fields.push(("type".to_string(), Json::from("counter")));
                    fields.push(("value".to_string(), Json::Num(v as f64)));
                }
                MetricValue::Gauge(v) => {
                    fields.push(("type".to_string(), Json::from("gauge")));
                    fields.push(("value".to_string(), Json::Num(v as f64)));
                }
                MetricValue::Histo { bounds, buckets, count, sum } => {
                    fields.push(("type".to_string(), Json::from("histogram")));
                    fields.push(("count".to_string(), Json::Num(count as f64)));
                    fields.push(("sum".to_string(), Json::Num((sum * 1e6).round() / 1e6)));
                    let bs: Vec<Json> = buckets
                        .iter()
                        .enumerate()
                        .map(|(i, &n)| {
                            let le = bounds.get(i).copied().unwrap_or(f64::INFINITY);
                            Json::Obj(vec![
                                ("le".to_string(), Json::Num(le)),
                                ("n".to_string(), Json::Num(n as f64)),
                            ])
                        })
                        .collect();
                    fields.push(("buckets".to_string(), Json::Arr(bs)));
                }
            }
            Json::Obj(fields)
        })
        .collect();
    let kernels: Vec<Json> = crate::obs::profile::KIND_NAMES
        .iter()
        .zip(crate::obs::profile::snapshot().iter())
        .map(|(name, k)| {
            Json::Obj(vec![
                ("kind".to_string(), Json::from(*name)),
                ("calls".to_string(), Json::Num(k.calls as f64)),
                ("ns".to_string(), Json::Num(k.ns as f64)),
                ("flops".to_string(), Json::Num(k.flops as f64)),
                ("gflops".to_string(), Json::Num((k.gflops() * 1e3).round() / 1e3)),
            ])
        })
        .collect();
    let lanes: Vec<Json> = crate::obs::profile::lane_snapshot(crate::kernels::pool::pool_threads())
        .iter()
        .map(|&ns| Json::Num(ns as f64))
        .collect();
    Json::Obj(vec![
        ("id".to_string(), Json::from("")),
        ("event".to_string(), Json::from("metrics")),
        ("uptime_secs".to_string(), Json::Num((obs.uptime_secs() * 1e3).round() / 1e3)),
        ("metrics".to_string(), Json::Arr(metrics)),
        ("kernels".to_string(), Json::Arr(kernels)),
        ("lanes_busy_ns".to_string(), Json::Arr(lanes)),
    ])
    .render()
}

/// Render the `{"cmd":"trace"}` response: `total` ticks ever recorded
/// plus the retained tail of the ring, oldest-first.
pub fn trace_frame(total: u64, ticks: &[TickRecord]) -> String {
    Json::Obj(vec![
        ("id".to_string(), Json::from("")),
        ("event".to_string(), Json::from("trace")),
        ("total".to_string(), Json::Num(total as f64)),
        ("ticks".to_string(), Json::Arr(ticks.iter().map(TickRecord::to_json).collect())),
    ])
    .render()
}

fn adapter_json(a: &AdapterStat) -> Json {
    Json::Obj(vec![
        ("name".to_string(), Json::from(a.name.as_str())),
        ("rank".to_string(), Json::from(a.rank)),
        ("n_adapted".to_string(), Json::from(a.n_adapted)),
        ("resident_bytes".to_string(), Json::from(a.resident_bytes)),
        ("refs".to_string(), Json::from(a.refs)),
        ("tokens".to_string(), Json::from(a.tokens as i64)),
        ("draining".to_string(), Json::Bool(a.draining)),
        (
            "delta_overhead".to_string(),
            Json::Num((a.delta_overhead * 1e6).round() / 1e6),
        ),
    ])
}

/// Render the ack frame for an `adapter` command.  `status` is one of
/// `"loaded"`, `"unloaded"`, or `"draining"` (deferred unload).
pub fn adapter_frame(op: AdapterOp, name: &str, status: &str) -> String {
    Json::Obj(vec![
        ("id".to_string(), Json::from("")),
        ("event".to_string(), Json::from("adapter")),
        ("op".to_string(), Json::from(op.as_str())),
        ("name".to_string(), Json::from(name)),
        ("status".to_string(), Json::from(status)),
    ])
    .render()
}

/// Render an error frame (empty `id` when the failure precedes parsing).
/// `code` is one of the [`code`] constants.
pub fn error_frame(id: &str, code: &str, message: &str) -> String {
    Json::Obj(vec![
        ("id".to_string(), Json::from(id)),
        ("event".to_string(), Json::from("error")),
        ("code".to_string(), Json::from(code)),
        ("message".to_string(), Json::from(message)),
    ])
    .render()
}

/// Render the overload-rejection frame: the request was NOT enqueued;
/// `retry_after_ms` hints when resubmission is likely to succeed.
pub fn overloaded_frame(id: &str, retry_after_ms: u64) -> String {
    Json::Obj(vec![
        ("id".to_string(), Json::from(id)),
        ("event".to_string(), Json::from("error")),
        ("code".to_string(), Json::from(code::OVERLOADED)),
        ("retry_after_ms".to_string(), Json::from(retry_after_ms as i64)),
        (
            "message".to_string(),
            Json::from("submission queue full; back off and resubmit"),
        ),
    ])
    .render()
}

/// Render the ack frame for `{"cmd":"drain"}` (and the SIGTERM path):
/// `in_flight` counts sequences still pending or decoding.
pub fn drain_frame(status: &str, in_flight: usize) -> String {
    Json::Obj(vec![
        ("id".to_string(), Json::from("")),
        ("event".to_string(), Json::from("drain")),
        ("status".to_string(), Json::from(status)),
        ("in_flight".to_string(), Json::from(in_flight)),
    ])
    .render()
}

/// Render one scheduler event as a protocol frame line (no newline).
pub fn event_frame(ev: &StepEvent) -> String {
    match ev {
        StepEvent::Token { id, index, token, .. } => Json::Obj(vec![
            ("id".to_string(), Json::from(id.as_str())),
            ("event".to_string(), Json::from("token")),
            ("index".to_string(), Json::from(*index)),
            ("token".to_string(), Json::from(*token as i64)),
        ])
        .render(),
        StepEvent::Done { id, tokens, prompt_len, finish, stats, .. } => {
            let generated: Vec<Json> =
                tokens[*prompt_len..].iter().map(|&t| Json::from(t as i64)).collect();
            Json::Obj(vec![
                ("id".to_string(), Json::from(id.as_str())),
                ("event".to_string(), Json::from("done")),
                ("finish".to_string(), Json::from(finish.as_str())),
                ("prompt_len".to_string(), Json::from(*prompt_len)),
                ("tokens".to_string(), Json::Arr(generated)),
                ("stats".to_string(), stats_json(stats)),
            ])
            .render()
        }
        StepEvent::Rejected { id, code, reason, .. } => error_frame(id, code, reason),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_request() {
        let line = r#"{"id":"a","prompt":[1,2,3]}"#;
        let ClientLine::Request(r) = parse_line(line).unwrap() else {
            panic!("expected request");
        };
        assert_eq!(r.id, "a");
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new, DEFAULT_MAX_NEW);
        assert!(r.sampling.is_none());
        assert!(r.stop.is_none());
        assert!(r.adapter.is_none());
    }

    #[test]
    fn parses_adapter_routing_and_cmds() {
        let ClientLine::Request(r) =
            parse_line(r#"{"id":"a","prompt":[1],"adapter":"taskA"}"#).unwrap()
        else {
            panic!("expected request");
        };
        assert_eq!(r.adapter.as_deref(), Some("taskA"));
        assert!(
            parse_line(r#"{"id":"a","prompt":[1],"adapter":7}"#).is_err(),
            "non-string adapter rejected"
        );

        assert_eq!(
            parse_line(r#"{"cmd":"adapter","op":"load","name":"t","path":"x.apq"}"#).unwrap(),
            ClientLine::Adapter {
                op: AdapterOp::Load,
                name: "t".to_string(),
                path: Some("x.apq".to_string())
            }
        );
        assert_eq!(
            parse_line(r#"{"cmd":"adapter","op":"unload","name":"t"}"#).unwrap(),
            ClientLine::Adapter { op: AdapterOp::Unload, name: "t".to_string(), path: None }
        );
        for bad in [
            r#"{"cmd":"adapter"}"#,
            r#"{"cmd":"adapter","op":"load","name":"t"}"#,
            r#"{"cmd":"adapter","op":"evict","name":"t"}"#,
            r#"{"cmd":"adapter","op":"load","path":"x.apq"}"#,
        ] {
            assert!(parse_line(bad).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn adapter_frame_is_parseable() {
        let f = adapter_frame(AdapterOp::Unload, "taskA", "draining");
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("adapter"));
        assert_eq!(j.get("op").and_then(Json::as_str), Some("unload"));
        assert_eq!(j.get("name").and_then(Json::as_str), Some("taskA"));
        assert_eq!(j.get("status").and_then(Json::as_str), Some("draining"));
    }

    #[test]
    fn parses_sampling_request() {
        let line =
            r#"{"id":"b","prompt":[7],"max_new":4,"temperature":0.8,"top_k":40,"top_p":0.9,"seed":3,"stop":0}"#;
        let ClientLine::Request(r) = parse_line(line).unwrap() else {
            panic!("expected request");
        };
        assert_eq!(r.max_new, 4);
        let s = r.sampling.unwrap();
        assert!((s.temperature - 0.8).abs() < 1e-6);
        assert_eq!(s.top_k, 40);
        assert!((s.top_p - 0.9).abs() < 1e-6);
        assert_eq!(s.seed, 3);
        assert_eq!(r.stop, Some(0));
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let line = r#"{"id":"c","prompt":[1],"temperature":0}"#;
        let ClientLine::Request(r) = parse_line(line).unwrap() else {
            panic!("expected request");
        };
        assert!(r.sampling.is_none());
    }

    #[test]
    fn parses_shutdown_and_stats() {
        assert_eq!(parse_line(r#"{"cmd":"shutdown"}"#).unwrap(), ClientLine::Shutdown);
        assert_eq!(parse_line(r#"{"cmd":"stats"}"#).unwrap(), ClientLine::Stats);
        assert!(parse_line(r#"{"cmd":"reboot"}"#).is_err());
    }

    #[test]
    fn parses_metrics_and_trace() {
        assert_eq!(parse_line(r#"{"cmd":"metrics"}"#).unwrap(), ClientLine::Metrics);
        assert_eq!(
            parse_line(r#"{"cmd":"trace"}"#).unwrap(),
            ClientLine::Trace { n: DEFAULT_TRACE_N }
        );
        assert_eq!(parse_line(r#"{"cmd":"trace","n":3}"#).unwrap(), ClientLine::Trace { n: 3 });
        // out-of-range asks clamp instead of erroring
        assert_eq!(parse_line(r#"{"cmd":"trace","n":0}"#).unwrap(), ClientLine::Trace { n: 1 });
        assert_eq!(
            parse_line(r#"{"cmd":"trace","n":999999}"#).unwrap(),
            ClientLine::Trace { n: MAX_TRACE_N }
        );
    }

    #[test]
    fn metrics_and_trace_frames_are_parseable() {
        let obs = Telemetry::new(8);
        obs.metrics.ticks_total.add(2);
        obs.metrics.tick_seconds.observe(0.01);
        obs.record_tick(TickRecord { batch: 3, tokens: 5, ..Default::default() });
        let f = metrics_frame(&obs);
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("metrics"));
        let ms = j.get("metrics").and_then(Json::as_arr).expect("metrics array");
        let ticks = ms
            .iter()
            .find(|m| m.get("name").and_then(Json::as_str) == Some("ticks_total"))
            .expect("ticks_total present");
        assert_eq!(ticks.get("value").and_then(Json::as_i64), Some(2));
        let hist = ms
            .iter()
            .find(|m| m.get("name").and_then(Json::as_str) == Some("tick_seconds"))
            .expect("tick_seconds present");
        let buckets = hist.get("buckets").and_then(Json::as_arr).expect("buckets");
        // overflow bucket's +Inf bound must render as null, not break JSON
        assert!(matches!(buckets.last().unwrap().get("le"), Some(Json::Null) | None));
        assert!(j.get("kernels").and_then(Json::as_arr).is_some());

        let (total, ticks) = obs.last_ticks(8);
        let f = trace_frame(total, &ticks);
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("trace"));
        assert_eq!(j.get("total").and_then(Json::as_i64), Some(1));
        let t0 = &j.get("ticks").and_then(Json::as_arr).expect("ticks array")[0];
        assert_eq!(t0.get("batch").and_then(Json::as_i64), Some(3));
        assert_eq!(t0.get("tokens").and_then(Json::as_i64), Some(5));
    }

    #[test]
    fn stats_frame_carries_kv_accounting() {
        let kv = crate::serve::block::KvStats {
            block_size: 4,
            blocks_total: 16,
            resident_blocks: 6,
            free_blocks: 1,
            used_blocks: 5,
            shared_blocks: 2,
            peak_resident_blocks: 6,
            peak_shared_blocks: 3,
            block_bytes: 256,
            resident_bytes: 1536,
            peak_resident_bytes: 1536,
            kv_bits: 16,
            f32_block_bytes: 256,
        };
        let build = crate::obs::build_info();
        let f = stats_frame(&EngineSnapshot {
            kv: &kv,
            active: 2,
            pending: 1,
            completed: 9,
            spec: None,
            tier: None,
            adapters: &[],
            baseline_tokens: 0,
            build: &build,
            uptime_secs: 1.25,
        });
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("stats"));
        assert_eq!(j.get("active").and_then(Json::as_i64), Some(2));
        assert_eq!(j.get("completed").and_then(Json::as_i64), Some(9));
        assert!((j.get("uptime_secs").and_then(Json::as_f64).unwrap() - 1.25).abs() < 1e-9);
        let bj = j.get("build").expect("build object");
        assert_eq!(bj.get("version").and_then(Json::as_str), Some(env!("CARGO_PKG_VERSION")));
        assert!(bj.get("kernel").and_then(Json::as_str).is_some());
        assert!(bj.get("threads").and_then(Json::as_i64).unwrap() >= 1);
        assert!(bj.get("features").and_then(Json::as_arr).is_some());
        let kvj = j.get("kv").expect("kv object");
        assert_eq!(kvj.get("block_size").and_then(Json::as_i64), Some(4));
        assert_eq!(kvj.get("shared_blocks").and_then(Json::as_i64), Some(2));
        assert_eq!(kvj.get("peak_shared_blocks").and_then(Json::as_i64), Some(3));
        assert_eq!(kvj.get("peak_resident_bytes").and_then(Json::as_i64), Some(1536));
        assert_eq!(kvj.get("kv_bits").and_then(Json::as_i64), Some(16));
        assert_eq!(kvj.get("f32_block_bytes").and_then(Json::as_i64), Some(256));
        // 1536 / (6 * 256) == 1.0 — f32 layout reports unit ratio.
        assert_eq!(kvj.get("resident_ratio").and_then(Json::as_f64), Some(1.0));
        assert!(j.get("spec").is_none(), "no spec object when not speculating");
        assert!(j.get("tier").is_none(), "no tier object without --kv-spill");
        assert_eq!(
            j.get("adapters").and_then(Json::as_arr).map(|a| a.len()),
            Some(0),
            "adapters array present even when the registry is empty"
        );

        let ad = crate::serve::adapters::AdapterStat {
            name: "taskA".to_string(),
            rank: 4,
            n_adapted: 28,
            resident_bytes: 1024,
            refs: 1,
            tokens: 64,
            draining: true,
            delta_overhead: 0.0215,
        };
        let spec = SpecStats {
            k: 4,
            proposed: 40,
            accepted: 30,
            cycles: 12,
            fallbacks: 1,
            draft_kv: kv,
        };
        let tier = TierStats {
            spilled_blocks: 12,
            spilled_bytes: 786_432,
            slots_resident: 16,
            slots_total: 0,
            spill_writes: 40,
            spill_reads: 28,
            preemptions: 3,
            resumes: 3,
            suspended: 1,
            block_restores: 28,
            restore_failures: 0,
            sessions_stored: 1,
            session_resumes: 2,
            prefix_pages: 4,
            prefix_hits: 5,
            prefix_misses: 2,
            promotes: 5,
            promote_secs_total: 0.0018,
        };
        let f = stats_frame(&EngineSnapshot {
            kv: &kv,
            active: 2,
            pending: 1,
            completed: 9,
            spec: Some(&spec),
            tier: Some(&tier),
            adapters: std::slice::from_ref(&ad),
            baseline_tokens: 120,
            build: &build,
            uptime_secs: 2.0,
        });
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("baseline_tokens").and_then(Json::as_i64), Some(120));
        let adj = &j.get("adapters").and_then(Json::as_arr).expect("adapters array")[0];
        assert_eq!(adj.get("name").and_then(Json::as_str), Some("taskA"));
        assert_eq!(adj.get("rank").and_then(Json::as_i64), Some(4));
        assert_eq!(adj.get("refs").and_then(Json::as_i64), Some(1));
        assert_eq!(adj.get("tokens").and_then(Json::as_i64), Some(64));
        assert_eq!(adj.get("draining").and_then(Json::as_bool), Some(true));
        assert!((adj.get("delta_overhead").and_then(Json::as_f64).unwrap() - 0.0215).abs() < 1e-9);
        let sj = j.get("spec").expect("spec object");
        assert_eq!(sj.get("k").and_then(Json::as_i64), Some(4));
        assert_eq!(sj.get("proposed").and_then(Json::as_i64), Some(40));
        assert_eq!(sj.get("accepted").and_then(Json::as_i64), Some(30));
        assert!((sj.get("acceptance").and_then(Json::as_f64).unwrap() - 0.75).abs() < 1e-9);
        assert_eq!(sj.get("fallbacks").and_then(Json::as_i64), Some(1));
        let dkv = sj.get("draft_kv").expect("draft kv accounting");
        assert_eq!(dkv.get("blocks_total").and_then(Json::as_i64), Some(16));
        let tj = j.get("tier").expect("tier object");
        assert_eq!(tj.get("spilled_blocks").and_then(Json::as_i64), Some(12));
        assert_eq!(tj.get("spilled_bytes").and_then(Json::as_i64), Some(786_432));
        assert_eq!(tj.get("slots_total").and_then(Json::as_i64), Some(0));
        assert_eq!(tj.get("preemptions").and_then(Json::as_i64), Some(3));
        assert_eq!(tj.get("suspended").and_then(Json::as_i64), Some(1));
        assert_eq!(tj.get("restore_failures").and_then(Json::as_i64), Some(0));
        assert_eq!(tj.get("sessions_stored").and_then(Json::as_i64), Some(1));
        assert_eq!(tj.get("session_resumes").and_then(Json::as_i64), Some(2));
        assert_eq!(tj.get("prefix_hits").and_then(Json::as_i64), Some(5));
        assert_eq!(tj.get("promotes").and_then(Json::as_i64), Some(5));
        assert!((tj.get("promote_ms_total").and_then(Json::as_f64).unwrap() - 1.8).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_requests() {
        for bad in [
            "not json",
            r#"{"prompt":[1]}"#,
            r#"{"id":"x"}"#,
            r#"{"id":"x","prompt":"nope"}"#,
            r#"{"id":"x","prompt":[1.5]}"#,
            r#"{"id":"x","prompt":[4294967296]}"#,
            r#"{"id":"x","prompt":[1],"stop":4294967296}"#,
        ] {
            assert!(parse_line(bad).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn frames_are_parseable_json() {
        use crate::serve::json::Json;
        let tok = StepEvent::Token { key: 1, id: "r".into(), index: 2, token: 99 };
        let f = event_frame(&tok);
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("token"));
        assert_eq!(j.get("index").and_then(Json::as_i64), Some(2));
        assert_eq!(j.get("token").and_then(Json::as_i64), Some(99));

        let done = StepEvent::Done {
            key: 1,
            id: "r".into(),
            tokens: vec![5, 6, 7, 8],
            prompt_len: 2,
            finish: crate::serve::scheduler::FinishReason::Length,
            stats: RequestStats {
                queue_secs: 0.001,
                prefill_secs: 0.002,
                total_secs: 0.01,
                max_inter_token_secs: 0.003,
                n_new_tokens: 2,
                shared_prefix_tokens: 1,
                spec_proposed: 4,
                spec_accepted: 3,
            },
        };
        let f = event_frame(&done);
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("done"));
        assert_eq!(j.get("finish").and_then(Json::as_str), Some("length"));
        let toks: Vec<i64> = j
            .get("tokens")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(toks, vec![7, 8], "done frame carries only generated tokens");
        assert!(j.get("stats").and_then(|s| s.get("queue_ms")).is_some());
        assert_eq!(
            j.get("stats").and_then(|s| s.get("spec_proposed")).and_then(Json::as_i64),
            Some(4),
            "done stats carry the per-request speculative counters"
        );

        let err = error_frame("x", code::BAD_REQUEST, "boom \"quoted\"");
        let j = Json::parse(&err).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("error"));
        assert_eq!(j.get("code").and_then(Json::as_str), Some("bad_request"));
    }

    #[test]
    fn parses_session_field() {
        let ClientLine::Request(r) =
            parse_line(r#"{"id":"a","prompt":[1],"session":"alice"}"#).unwrap()
        else {
            panic!("expected request");
        };
        assert_eq!(r.session.as_deref(), Some("alice"));
        let ClientLine::Request(r) = parse_line(r#"{"id":"a","prompt":[1]}"#).unwrap() else {
            panic!("expected request");
        };
        assert!(r.session.is_none(), "omitted session stays anonymous");
        for bad in [
            r#"{"id":"a","prompt":[1],"session":7}"#,
            r#"{"id":"a","prompt":[1],"session":""}"#,
        ] {
            assert!(parse_line(bad).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn parses_deadline_and_drain() {
        let ClientLine::Request(r) =
            parse_line(r#"{"id":"a","prompt":[1],"deadline_ms":250}"#).unwrap()
        else {
            panic!("expected request");
        };
        assert_eq!(r.deadline_ms, Some(250));
        let ClientLine::Request(r) = parse_line(r#"{"id":"a","prompt":[1]}"#).unwrap() else {
            panic!("expected request");
        };
        assert_eq!(r.deadline_ms, None, "omitted deadline defers to the server default");
        for bad in [
            r#"{"id":"a","prompt":[1],"deadline_ms":0}"#,
            r#"{"id":"a","prompt":[1],"deadline_ms":-5}"#,
            r#"{"id":"a","prompt":[1],"deadline_ms":"soon"}"#,
        ] {
            assert!(parse_line(bad).is_err(), "should reject {bad}");
        }
        assert_eq!(parse_line(r#"{"cmd":"drain"}"#).unwrap(), ClientLine::Drain);
    }

    #[test]
    fn overload_and_drain_frames_are_parseable() {
        let f = overloaded_frame("r9", 75);
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("error"));
        assert_eq!(j.get("code").and_then(Json::as_str), Some("overloaded"));
        assert_eq!(j.get("retry_after_ms").and_then(Json::as_i64), Some(75));

        let f = drain_frame("draining", 3);
        let j = Json::parse(&f).unwrap();
        assert_eq!(j.get("event").and_then(Json::as_str), Some("drain"));
        assert_eq!(j.get("status").and_then(Json::as_str), Some("draining"));
        assert_eq!(j.get("in_flight").and_then(Json::as_i64), Some(3));
    }
}
