//! The spill file: a flat, slot-granular second tier for KV pages.
//!
//! A [`SpillFile`] stores [`crate::serve::BlockPool::export_block`]
//! records — one KV page per slot — in a single append/recycle file.
//! Slots are fixed-size (the pool's `max_export_bytes`, so a staged f32
//! page and a sealed quantized page share one geometry), addressed by a
//! dense `u64` id, and recycled through an in-memory free list.  The
//! file is truncated at boot: the tier is a *spill* target (an extension
//! of RAM for the current process), not a database — nothing in it is
//! meaningful across restarts, which is why no on-disk allocation state
//! exists.
//!
//! ## On-disk format
//!
//! ```text
//! header (64 bytes):  "APIQSPIL" | version u32 LE | slot_bytes u64 LE | zero pad
//! slot i at 64 + i * (8 + slot_bytes):
//!                     crc32 u32 LE | payload_len u32 LE | payload | pad
//! ```
//!
//! Every read verifies the stored CRC32 (same table as the checkpoint
//! trailers) before handing bytes back; a mismatch — or a fired
//! `spill_io` fault point — surfaces as an error the scheduler turns
//! into an `internal` finish for the one affected sequence.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::model::checkpoint::crc32;
use crate::obs::{FaultPlan, FaultPoint};

const MAGIC: &[u8; 8] = b"APIQSPIL";
const VERSION: u32 = 1;
const HEADER_BYTES: u64 = 64;
/// Per-slot on-disk prefix: CRC32 + payload length.
const SLOT_HEADER: usize = 8;

/// Aggregate spill-file statistics (stats frame + Prometheus).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpillStats {
    /// Slot capacity (0 = unbounded).
    pub slots_total: usize,
    /// Slots ever created (file extent).
    pub slots_resident: usize,
    /// Slots currently holding a live page.
    pub slots_used: usize,
    /// Live payload bytes on disk.
    pub bytes_used: u64,
    /// Slot writes so far.
    pub writes: u64,
    /// Slot reads so far (successful or not).
    pub reads: u64,
}

/// Slot-granular spill file (format in the module docs).
pub struct SpillFile {
    file: File,
    /// Max payload bytes one slot can hold.
    slot_bytes: usize,
    /// Slot budget; 0 = grow without bound.
    max_slots: usize,
    /// Slots ever appended (dense ids `0..next_slot`).
    next_slot: u64,
    free: Vec<u64>,
    /// Live payload length per slot id (0 = free).
    lens: Vec<u32>,
    bytes_used: u64,
    writes: u64,
    reads: u64,
    fault: Option<Arc<FaultPlan>>,
}

impl SpillFile {
    /// Create (truncating) the spill file at `path` with `slot_bytes`
    /// payload capacity per slot and a budget of `max_slots` slots
    /// (0 = unbounded).
    pub fn create(path: &str, slot_bytes: usize, max_slots: usize) -> Result<SpillFile> {
        if slot_bytes == 0 {
            return Err(Error::config("kv spill: slot size must be nonzero"));
        }
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .map_err(|e| Error::config(format!("kv spill: cannot create '{path}': {e}")))?;
        let mut header = [0u8; HEADER_BYTES as usize];
        header[..8].copy_from_slice(MAGIC);
        header[8..12].copy_from_slice(&VERSION.to_le_bytes());
        header[12..20].copy_from_slice(&(slot_bytes as u64).to_le_bytes());
        file.write_all(&header)
            .map_err(|e| Error::config(format!("kv spill: header write failed: {e}")))?;
        Ok(SpillFile {
            file,
            slot_bytes,
            max_slots,
            next_slot: 0,
            free: Vec::new(),
            lens: Vec::new(),
            bytes_used: 0,
            writes: 0,
            reads: 0,
            fault: None,
        })
    }

    /// Arm the `spill_io` fault-injection point (`--fault spill_io:...`).
    pub fn set_fault(&mut self, plan: Arc<FaultPlan>) {
        self.fault = Some(plan);
    }

    /// Max payload bytes one slot holds.
    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    /// Slots `write_slot` could hand out right now without exceeding the
    /// budget (`usize::MAX` when unbounded).
    pub fn available(&self) -> usize {
        if self.max_slots == 0 {
            usize::MAX
        } else {
            self.free.len() + self.max_slots.saturating_sub(self.next_slot as usize)
        }
    }

    fn offset(&self, slot: u64) -> u64 {
        HEADER_BYTES + slot * (SLOT_HEADER + self.slot_bytes) as u64
    }

    /// Store one page record, recycling a freed slot when possible.
    /// Errors when the payload exceeds the slot size or the slot budget
    /// is exhausted — the caller backs out of the spill (the sequence
    /// finishes the way it would have without a tier).
    pub fn write_slot(&mut self, payload: &[u8]) -> Result<u64> {
        if payload.len() > self.slot_bytes {
            return Err(Error::config(format!(
                "kv spill: page record of {} bytes exceeds slot size {}",
                payload.len(),
                self.slot_bytes
            )));
        }
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                if self.max_slots > 0 && self.next_slot as usize >= self.max_slots {
                    return Err(Error::config(format!(
                        "kv spill: slot budget exhausted ({} slots)",
                        self.max_slots
                    )));
                }
                let s = self.next_slot;
                self.next_slot += 1;
                self.lens.push(0);
                s
            }
        };
        let mut rec = Vec::with_capacity(SLOT_HEADER + payload.len());
        rec.extend_from_slice(&crc32(payload).to_le_bytes());
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(payload);
        let off = self.offset(slot);
        let res = self
            .file
            .seek(SeekFrom::Start(off))
            .and_then(|_| self.file.write_all(&rec));
        if let Err(e) = res {
            self.free.push(slot);
            return Err(Error::config(format!("kv spill: slot {slot} write failed: {e}")));
        }
        self.lens[slot as usize] = payload.len() as u32;
        self.bytes_used += payload.len() as u64;
        self.writes += 1;
        Ok(slot)
    }

    /// Read one page record back, verifying its CRC32.  The slot stays
    /// live — callers pair this with [`SpillFile::free_slot`] when the
    /// page moves back to RAM for good (suspend/resume), and leave it
    /// live for shared read-many records (prefix store).  Evaluates the
    /// `spill_io` fault point: a fired fault reports as a CRC-style
    /// corruption error.
    pub fn read_slot(&mut self, slot: u64) -> Result<Vec<u8>> {
        self.reads += 1;
        if let Some(f) = &self.fault {
            if f.fires(FaultPoint::SpillIo) {
                return Err(Error::config(format!(
                    "kv spill: slot {slot} read failed (injected fault)"
                )));
            }
        }
        if slot >= self.next_slot || self.lens[slot as usize] == 0 {
            return Err(Error::config(format!("kv spill: read of dead slot {slot}")));
        }
        let off = self.offset(slot);
        let mut head = [0u8; SLOT_HEADER];
        self.file
            .seek(SeekFrom::Start(off))
            .and_then(|_| self.file.read_exact(&mut head))
            .map_err(|e| Error::config(format!("kv spill: slot {slot} read failed: {e}")))?;
        let want = u32::from_le_bytes([head[0], head[1], head[2], head[3]]);
        let len = u32::from_le_bytes([head[4], head[5], head[6], head[7]]) as usize;
        if len > self.slot_bytes {
            return Err(Error::config(format!(
                "kv spill: slot {slot} header claims {len} bytes (slot size {})",
                self.slot_bytes
            )));
        }
        let mut payload = vec![0u8; len];
        self.file
            .read_exact(&mut payload)
            .map_err(|e| Error::config(format!("kv spill: slot {slot} read failed: {e}")))?;
        let got = crc32(&payload);
        if got != want {
            return Err(Error::config(format!(
                "kv spill: slot {slot} CRC32 mismatch (stored {want:#010x}, computed \
                 {got:#010x}) — record corrupt"
            )));
        }
        Ok(payload)
    }

    /// Return `slot` to the free list.
    pub fn free_slot(&mut self, slot: u64) {
        debug_assert!(slot < self.next_slot, "free of an unknown slot");
        let len = std::mem::take(&mut self.lens[slot as usize]);
        debug_assert!(len > 0, "double free of slot {slot}");
        self.bytes_used -= len as u64;
        self.free.push(slot);
    }

    /// Snapshot of slot occupancy and traffic counters.
    pub fn stats(&self) -> SpillStats {
        SpillStats {
            slots_total: self.max_slots,
            slots_resident: self.next_slot as usize,
            slots_used: self.next_slot as usize - self.free.len(),
            bytes_used: self.bytes_used,
            writes: self.writes,
            reads: self.reads,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!("repro-spill-{}-{name}.bin", std::process::id()));
        p.to_string_lossy().into_owned()
    }

    #[test]
    fn write_read_free_recycle() {
        let path = tmp("basic");
        let mut f = SpillFile::create(&path, 64, 2).unwrap();
        let a = f.write_slot(&[1, 2, 3]).unwrap();
        let b = f.write_slot(&vec![9u8; 64]).unwrap();
        assert_ne!(a, b);
        assert_eq!(f.read_slot(a).unwrap(), vec![1, 2, 3]);
        assert_eq!(f.read_slot(b).unwrap(), vec![9u8; 64]);
        assert!(f.write_slot(&[0]).is_err(), "budget of 2 slots is exhausted");
        assert!(f.write_slot(&vec![0u8; 65]).is_err(), "oversized payload rejected");

        f.free_slot(a);
        let c = f.write_slot(&[7, 7]).unwrap();
        assert_eq!(c, a, "freed slot is recycled, not grown");
        assert_eq!(f.read_slot(c).unwrap(), vec![7, 7]);
        let s = f.stats();
        assert_eq!((s.slots_resident, s.slots_used), (2, 2));
        assert_eq!(s.bytes_used, 64 + 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let path = tmp("corrupt");
        let mut f = SpillFile::create(&path, 32, 0).unwrap();
        let a = f.write_slot(&[5u8; 16]).unwrap();
        // flip one payload byte behind the CRC's back
        let mut raw = std::fs::read(&path).unwrap();
        let off = 64 + 8 + 3;
        raw[off] ^= 0xff;
        std::fs::write(&path, &raw).unwrap();
        // swap the live handle for one on the rewritten file
        f.file = OpenOptions::new().read(true).write(true).open(&path).unwrap();
        let err = f.read_slot(a).unwrap_err().to_string();
        assert!(err.contains("CRC32 mismatch"), "got: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn spill_io_fault_fails_reads_deterministically() {
        let path = tmp("fault");
        let mut f = SpillFile::create(&path, 16, 0).unwrap();
        f.set_fault(Arc::new(FaultPlan::parse("spill_io:@2:3").unwrap()));
        let a = f.write_slot(&[1]).unwrap();
        assert!(f.read_slot(a).is_ok(), "1st read clean");
        assert!(f.read_slot(a).is_err(), "2nd read injected to fail");
        assert!(f.read_slot(a).is_ok(), "one-shot fault clears");
        std::fs::remove_file(&path).ok();
    }
}
