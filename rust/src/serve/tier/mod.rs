//! Tiered KV: a disk-backed second tier behind the [`BlockPool`].
//!
//! The pool is RAM-budgeted; before this module, hitting the budget
//! meant admission backoff, `capacity` finishes, or outright rejection —
//! concurrency hard-capped by memory even though most resident pages at
//! any instant belong to sequences that are not decoding *right now*.
//! [`TieredKv`] adds the missing level of the hierarchy: cold pages move
//! to a slot-granular [`SpillFile`] **verbatim** (the pool's
//! `export_block` bytes, staged or sealed, CRC-checked on the way back),
//! so a restored page is bit-identical to the one that left and token
//! streams with spill enabled are bitwise what a memory-only run emits.
//!
//! Three consumers share the file:
//!
//! * **Preempt-to-spill** (scheduler): under block exhaustion the
//!   scheduler suspends a victim sequence — its block table is exported
//!   to slots and its pool pages freed — instead of refusing admission;
//!   the suspended sequence resumes when pages free up.
//! * **Sessions**: a request tagged `"session":"id"` leaves its final KV
//!   state spilled when it finishes (or its connection dies); a later
//!   request with the same session id and a prompt extending the stored
//!   history restores the pages and continues decoding without
//!   re-prefilling the shared positions.
//! * **Prefix store**: fully committed prompt-prefix pages are published
//!   under a rolling content key (chained over page token ids, with the
//!   full token prefix stored alongside for exact verification — a hash
//!   collision can never substitute wrong KV).  New requests from any
//!   connection, any time, fork popular prefixes with promote-on-read
//!   from disk, extending same-tick CoW sharing across connections and
//!   across time.  Prefix slots are read-shared and never freed (no
//!   eviction policy; insertion is budget-gated instead).
//!
//! Restore failures (bad CRC, I/O error, fired `spill_io` fault) are
//! contained: the affected sequence finishes `internal`, the engine and
//! every other sequence keep going.

pub mod spill;

pub use spill::{SpillFile, SpillStats};

use std::collections::HashMap;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::obs::FaultPlan;
use crate::serve::block::BlockPool;

/// One suspended session: everything needed to continue decoding later.
pub struct SessionEntry {
    /// Full token history (prompt + emitted tokens).
    pub tokens: Vec<i32>,
    /// Committed KV positions the spilled pages hold (always
    /// `tokens.len() - 1`: the final emitted token was never fed back).
    pub kv_len: usize,
    /// Spill slots, ascending page order.
    pub slots: Vec<u64>,
    /// Adapter the session was running (resume must match).
    pub adapter: Option<String>,
}

/// One published prefix page.
struct PrefixNode {
    slot: u64,
    /// The full token prefix through this page — exact verification, so
    /// a chain-hash collision cannot alias two different prefixes.
    prefix: Vec<i32>,
}

/// Aggregate tier statistics (stats frame + Prometheus).
#[derive(Clone, Copy, Debug, Default)]
pub struct TierStats {
    /// Spill slots currently holding a live page.
    pub spilled_blocks: usize,
    /// Live payload bytes on disk.
    pub spilled_bytes: u64,
    /// Slots ever created (file extent).
    pub slots_resident: usize,
    /// Slot budget (0 = unbounded).
    pub slots_total: usize,
    pub spill_writes: u64,
    pub spill_reads: u64,
    /// Sequences preempted to disk so far.
    pub preemptions: u64,
    /// Suspended sequences resumed so far.
    pub resumes: u64,
    /// Sequences suspended right now (scheduler fills this in).
    pub suspended: usize,
    /// Pages restored from disk so far.
    pub block_restores: u64,
    /// Failed restores (CRC / I/O / injected faults).
    pub restore_failures: u64,
    /// Sessions parked on disk right now.
    pub sessions_stored: usize,
    /// Session continuations served from spilled state.
    pub session_resumes: u64,
    /// Prefix pages published right now.
    pub prefix_pages: usize,
    /// Admissions that reused at least one stored prefix page.
    pub prefix_hits: u64,
    /// Admissions that consulted the store and found nothing.
    pub prefix_misses: u64,
    /// Prefix promotions (disk -> pool page runs) so far.
    pub promotes: u64,
    /// Wall-clock spent promoting, for the latency histogram.
    pub promote_secs_total: f64,
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Rolling content key of one page given its predecessor's key: mixing
/// the previous key into every token hash chains the whole prefix, so
/// page `k`'s key commits to tokens `[0, (k+1) * block_size)`.
fn chain_key(prev: u64, page: &[i32]) -> u64 {
    let mut h = splitmix64(prev ^ 0xA1B2_C3D4_E5F6_0718);
    for &t in page {
        h = splitmix64(h ^ (t as u64));
    }
    h
}

/// The disk tier: spill file + suspended sessions + prefix store.
pub struct TieredKv {
    spill: SpillFile,
    sessions: HashMap<String, SessionEntry>,
    /// Chain key -> published page; `None` when `--prefix-store` is off.
    prefix: Option<HashMap<u64, PrefixNode>>,
    preemptions: u64,
    resumes: u64,
    block_restores: u64,
    restore_failures: u64,
    session_resumes: u64,
    prefix_hits: u64,
    prefix_misses: u64,
    promotes: u64,
    promote_secs_total: f64,
}

impl TieredKv {
    /// Open the tier: create (truncate) the spill file at `path` with
    /// slots sized for `pool`'s largest page record, budgeted to
    /// `max_slots` slots (0 = unbounded), with the prefix store on or
    /// off.
    pub fn new(
        path: &str,
        pool: &BlockPool,
        max_slots: usize,
        prefix_store: bool,
    ) -> Result<TieredKv> {
        let spill = SpillFile::create(path, pool.max_export_bytes(), max_slots)?;
        Ok(TieredKv {
            spill,
            sessions: HashMap::new(),
            prefix: prefix_store.then(HashMap::new),
            preemptions: 0,
            resumes: 0,
            block_restores: 0,
            restore_failures: 0,
            session_resumes: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            promotes: 0,
            promote_secs_total: 0.0,
        })
    }

    /// Arm the `spill_io` fault point on the underlying file.
    pub fn set_fault(&mut self, plan: Arc<FaultPlan>) {
        self.spill.set_fault(plan);
    }

    /// Whether `n` more pages fit in the slot budget right now.
    pub fn can_spill(&self, n: usize) -> bool {
        self.spill.available() >= n
    }

    /// Export every block of `table` to spill slots (ascending page
    /// order).  All-or-nothing: a mid-way failure frees the slots already
    /// written and returns the error, leaving the pool pages untouched.
    pub fn spill_table(&mut self, pool: &BlockPool, table: &[usize]) -> Result<Vec<u64>> {
        let mut slots = Vec::with_capacity(table.len());
        for &id in table {
            match self.spill.write_slot(&pool.export_block(id)) {
                Ok(s) => slots.push(s),
                Err(e) => {
                    self.free_slots(&slots);
                    return Err(e);
                }
            }
        }
        Ok(slots)
    }

    /// Restore a spilled page run into freshly allocated pool blocks,
    /// returning the new block table (ascending page order).  The caller
    /// must have checked `pool.available() >= slots.len()`.
    /// All-or-nothing: any failure releases the blocks acquired so far
    /// and returns the error (slots are left live either way — the
    /// caller decides their fate).  When `free_slots` is set, a
    /// successful restore returns the slots to the free list (the page
    /// moved back to RAM for good); leave it unset for read-shared
    /// prefix slots.
    pub fn restore_table(
        &mut self,
        pool: &mut BlockPool,
        slots: &[u64],
        free_slots: bool,
    ) -> Result<Vec<usize>> {
        let mut table = Vec::with_capacity(slots.len());
        let mut failed: Option<Error> = None;
        for &slot in slots {
            let bytes = match self.spill.read_slot(slot) {
                Ok(b) => b,
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            };
            let id = match pool.try_alloc() {
                Some(id) => id,
                None => {
                    failed = Some(Error::config("kv spill: pool exhausted mid-restore"));
                    break;
                }
            };
            if let Err(e) = pool.import_block(id, &bytes) {
                pool.release(id);
                failed = Some(e);
                break;
            }
            table.push(id);
            self.block_restores += 1;
        }
        if let Some(e) = failed {
            for &id in &table {
                pool.release(id);
            }
            self.restore_failures += 1;
            return Err(e);
        }
        if free_slots {
            self.free_slots(slots);
        }
        Ok(table)
    }

    /// Return a batch of slots to the free list.
    pub fn free_slots(&mut self, slots: &[u64]) {
        for &s in slots {
            self.spill.free_slot(s);
        }
    }

    /// Count one scheduler preemption / one resumed sequence.
    pub fn note_preemption(&mut self) {
        self.preemptions += 1;
    }

    pub fn note_resume(&mut self) {
        self.resumes += 1;
    }

    // -- sessions ----------------------------------------------------------

    /// Park a finished-or-disconnected session's spilled state.  A
    /// same-id session already parked is replaced (its slots freed) —
    /// last writer wins, exactly like a client re-running a turn.
    pub fn store_session(&mut self, id: String, entry: SessionEntry) {
        if let Some(old) = self.sessions.insert(id, entry) {
            self.free_slots(&old.slots);
        }
    }

    /// Peek a parked session (resume admission checks the prompt
    /// extends the stored history before committing).
    pub fn session(&self, id: &str) -> Option<&SessionEntry> {
        self.sessions.get(id)
    }

    /// Claim a parked session for resume; the caller now owns its slots.
    pub fn take_session(&mut self, id: &str) -> Option<SessionEntry> {
        let e = self.sessions.remove(id);
        if e.is_some() {
            self.session_resumes += 1;
        }
        e
    }

    /// Discard a parked session and free its slots.
    pub fn drop_session(&mut self, id: &str) {
        if let Some(e) = self.sessions.remove(id) {
            self.free_slots(&e.slots);
        }
    }

    // -- prefix store ------------------------------------------------------

    /// Whether the content-keyed prefix store is enabled.
    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Longest stored page run matching `prompt`'s leading full pages:
    /// returns the slots, ascending page order (empty = no match).  Each
    /// matched page is verified against the *full* stored token prefix,
    /// so a match is exact by construction.  Counts one hit or miss when
    /// the store is enabled and the prompt has at least one full page.
    pub fn prefix_match(&mut self, prompt: &[i32], block_size: usize) -> Vec<u64> {
        let Some(nodes) = &self.prefix else { return Vec::new() };
        if prompt.len() < block_size {
            return Vec::new();
        }
        let mut slots = Vec::new();
        let mut key = 0u64;
        let mut upto = block_size;
        while upto <= prompt.len() {
            key = chain_key(key, &prompt[upto - block_size..upto]);
            match nodes.get(&key) {
                Some(n) if n.prefix == prompt[..upto] => slots.push(n.slot),
                _ => break,
            }
            upto += block_size;
        }
        if slots.is_empty() {
            self.prefix_misses += 1;
        } else {
            self.prefix_hits += 1;
        }
        slots
    }

    /// Publish the leading `pages` fully committed prompt pages of a
    /// running sequence (called after `seal_committed`, so under a
    /// quantized layout the exported pages are sealed).  Pages already
    /// published under the same chain key are skipped; new pages are
    /// budget-gated (insertion simply stops when the slot budget is
    /// full).  Returns how many leading pages are now covered, which the
    /// scheduler remembers per sequence to avoid re-walking every tick.
    pub fn publish_prefix(
        &mut self,
        pool: &BlockPool,
        prompt: &[i32],
        table: &[usize],
        pages: usize,
    ) -> usize {
        if self.prefix.is_none() {
            return 0;
        }
        let bs = pool.block_size();
        let mut key = 0u64;
        let mut done = 0usize;
        for k in 0..pages.min(table.len()) {
            let upto = (k + 1) * bs;
            if upto > prompt.len() {
                break;
            }
            key = chain_key(key, &prompt[upto - bs..upto]);
            let nodes = self.prefix.as_ref().unwrap();
            if !nodes.contains_key(&key) {
                if self.spill.available() == 0 {
                    break;
                }
                let Ok(slot) = self.spill.write_slot(&pool.export_block(table[k])) else {
                    break;
                };
                self.prefix
                    .as_mut()
                    .unwrap()
                    .insert(key, PrefixNode { slot, prefix: prompt[..upto].to_vec() });
            }
            done = k + 1;
        }
        done
    }

    /// Count one prefix promotion of `secs` wall-clock.
    pub fn note_promote(&mut self, secs: f64) {
        self.promotes += 1;
        self.promote_secs_total += secs;
    }

    /// Snapshot (the scheduler fills in `suspended`).
    pub fn stats(&self) -> TierStats {
        let s = self.spill.stats();
        TierStats {
            spilled_blocks: s.slots_used,
            spilled_bytes: s.bytes_used,
            slots_resident: s.slots_resident,
            slots_total: s.slots_total,
            spill_writes: s.writes,
            spill_reads: s.reads,
            preemptions: self.preemptions,
            resumes: self.resumes,
            suspended: 0,
            block_restores: self.block_restores,
            restore_failures: self.restore_failures,
            sessions_stored: self.sessions.len(),
            session_resumes: self.session_resumes,
            prefix_pages: self.prefix.as_ref().map_or(0, |m| m.len()),
            prefix_hits: self.prefix_hits,
            prefix_misses: self.prefix_misses,
            promotes: self.promotes,
            promote_secs_total: self.promote_secs_total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::block::KvLayout;
    use crate::serve::paged::PagedKvCache;

    fn tmp(name: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!("repro-tier-{}-{name}.bin", std::process::id()));
        p.to_string_lossy().into_owned()
    }

    fn filled_pool(layout: KvLayout) -> (BlockPool, PagedKvCache) {
        let (layers, d, bs) = (2usize, 8usize, 4usize);
        let mut pool = BlockPool::with_layout(layers, d, bs, 8, layout);
        let mut c = PagedKvCache::new(&pool);
        c.reserve(7, &mut pool).unwrap();
        for layer in 0..layers {
            let k: Vec<f32> = (0..7 * d).map(|i| (i as f32 * 0.9 + layer as f32).sin()).collect();
            let v: Vec<f32> = (0..7 * d).map(|i| (i as f32 * 0.4 - layer as f32).cos()).collect();
            c.write_rows(&mut pool, layer, &k, &v).unwrap();
        }
        c.advance(7);
        c.seal_committed(&mut pool);
        (pool, c)
    }

    #[test]
    fn spill_restore_roundtrip_preserves_bytes() {
        for layout in [
            KvLayout::F32,
            KvLayout::Quant { bits: 8, group: 8 },
            KvLayout::Quant { bits: 4, group: 8 },
        ] {
            let (mut pool, mut c) = filled_pool(layout);
            let path = tmp(&format!("rt{}", pool.kv_bits()));
            let mut tier = TieredKv::new(&path, &pool, 0, false).unwrap();
            let before: Vec<Vec<u8>> =
                c.table().iter().map(|&id| pool.export_block(id)).collect();

            let slots = tier.spill_table(&pool, c.table()).unwrap();
            c.release_all(&mut pool);
            assert_eq!(tier.stats().spilled_blocks, 2);

            let table = tier.restore_table(&mut pool, &slots, true).unwrap();
            let c2 = PagedKvCache::from_parts(&pool, table, 7);
            let after: Vec<Vec<u8>> =
                c2.table().iter().map(|&id| pool.export_block(id)).collect();
            assert_eq!(before, after, "restored pages must be byte-identical ({layout:?})");
            assert_eq!(tier.stats().spilled_blocks, 0, "slots freed after restore");
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn sessions_park_and_resume_once() {
        let (pool, _c) = filled_pool(KvLayout::F32);
        let path = tmp("sess");
        let mut tier = TieredKv::new(&path, &pool, 0, false).unwrap();
        tier.store_session(
            "a".into(),
            SessionEntry { tokens: vec![1, 2, 3], kv_len: 2, slots: vec![], adapter: None },
        );
        assert_eq!(tier.stats().sessions_stored, 1);
        assert!(tier.session("a").is_some());
        let e = tier.take_session("a").unwrap();
        assert_eq!(e.tokens, vec![1, 2, 3]);
        assert!(tier.take_session("a").is_none(), "claimed once");
        assert_eq!(tier.stats().session_resumes, 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn prefix_store_publishes_and_matches_exactly() {
        let (pool, c) = filled_pool(KvLayout::Quant { bits: 8, group: 8 });
        let path = tmp("prefix");
        let mut tier = TieredKv::new(&path, &pool, 0, true).unwrap();
        let prompt: Vec<i32> = (0..7).collect();

        // only the one fully committed page (bs 4, len 7) is publishable
        let done = tier.publish_prefix(&pool, &prompt, c.table(), 1);
        assert_eq!(done, 1);
        assert_eq!(tier.stats().prefix_pages, 1);
        // republish is a no-op
        assert_eq!(tier.publish_prefix(&pool, &prompt, c.table(), 1), 1);
        assert_eq!(tier.stats().prefix_pages, 1);

        // same leading page matches, regardless of what follows
        assert_eq!(tier.prefix_match(&[0, 1, 2, 3, 9, 9], 4).len(), 1);
        // different token in the covered range: no match (exact verify)
        assert!(tier.prefix_match(&[0, 1, 2, 9, 9, 9], 4).is_empty());
        // shorter than a page: no consult
        assert!(tier.prefix_match(&[0, 1, 2], 4).is_empty());
        let s = tier.stats();
        assert_eq!((s.prefix_hits, s.prefix_misses), (1, 1));
        std::fs::remove_file(&path).ok();
    }
}
