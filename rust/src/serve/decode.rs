//! KV-cached incremental decoding: O(T) per emitted token.
//!
//! Two storage layouts share one decode engine:
//!
//! * **Flat** — [`KvCache`] slabs, one worst-case buffer per sequence
//!   ([`PackedModel::forward_chunk`] / [`PackedModel::forward_step`]).
//!   Kept alive as the reference path: paged decode is asserted bitwise
//!   identical to it, the same way [`generate_recompute`] anchors the
//!   cached path against full-prefix recompute.
//! * **Paged** — [`PagedKvCache`] block tables over a shared
//!   [`BlockPool`] ([`PackedModel::forward_chunk_paged`] /
//!   [`PackedModel::forward_step_paged`] /
//!   [`PackedModel::prefill_batch`]).  Attention walks per-page K/V
//!   views in ascending-position order through the same
//!   [`attend_segs`] core the flat path uses (flat = a single segment),
//!   so the score, softmax, and value-accumulation order — and therefore
//!   every output bit — match the flat layout exactly.
//!
//! [`PackedModel::prefill_batch`] folds several sequences' prefill
//! chunks into ONE pass: the linears run over the ragged row
//! concatenation (every per-position op is row-independent, so batching
//! changes no bits), attention runs per sequence against its own block
//! table.  Within each layer every sequence's K/V rows are written
//! before any sequence attends, which is what lets same-tick admissions
//! share prompt-prefix blocks that are materialized in the very same
//! pass.
//!
//! [`PackedModel::forward_verify_paged`] is the same ragged batched
//! pass surfacing logits at EVERY position instead of just the last
//! rows — the speculative-decoding verify primitive
//! (`crate::serve::spec`): each row is bitwise what the corresponding
//! sequential decode step would have produced, which is what makes
//! draft acceptance checks exact.
//!
//! [`generate`] (flat) and [`generate_paged`] are the batched decode
//! loops on top; [`generate_recompute`] keeps PR 1's full-prefix
//! recompute alive as the outermost equivalence reference and benchmark
//! baseline.

use std::time::Instant;

use crate::error::{Error, Result};
use crate::infer::{
    apply_rope, argmax, rmsnorm_rows, AdapterSet, GenReport, PackedBlock, PackedModel, RopeView,
    SLOT_WDOWN, SLOT_WGATE, SLOT_WK, SLOT_WO, SLOT_WQ, SLOT_WUP, SLOT_WV,
};
use crate::kernels;
use crate::kernels::dequant::{kv_row_accum, kv_row_dequant};
use crate::serve::block::{BlockPool, KvSegment};
use crate::serve::kv::KvCache;
use crate::serve::paged::PagedKvCache;
use crate::serve::sampling::{sample, seq_rng, SamplingParams};
use crate::tensor::{IntTensor, Rng, Tensor};

/// One sequence's contiguous row range in a batched projection plus the
/// adapter set routed for it: `(first row, row count, set)`.
pub(crate) type AdapterSpan<'a> = (usize, usize, Option<&'a AdapterSet>);

/// Add per-sequence adapter deltas to the output `y` (n, d_out) of a
/// shared base projection over `x` (n, d_in).  The base GEMV has already
/// run ONCE over every row; here the rows of sequences that resolve to
/// the same [`crate::infer::Adapter`] for `(li, slot)` are gathered into
/// ONE low-rank delta GEMM pair (`scale·(x·A)·Bᵀ` + DoRA column rescale),
/// then scattered back.  The kernels are bitwise row-stable across batch
/// shapes, so each row's result is identical to a solo run of its own
/// adapter — and when every row resolves to one adapter in batch order
/// (the single-pairing case), `x` is used directly, reproducing the old
/// baked-in path's single whole-batch GEMM bit for bit.
fn apply_adapter_deltas(
    y: &mut Tensor,
    x: &Tensor,
    spans: &[AdapterSpan<'_>],
    li: usize,
    slot: usize,
) -> Result<()> {
    let d_in = x.shape()[1];
    let d_out = y.shape()[1];
    let n_rows = x.shape()[0];
    let mut done = vec![false; spans.len()];
    for i in 0..spans.len() {
        if done[i] {
            continue;
        }
        done[i] = true;
        let ad = match spans[i].2.and_then(|s| s.get(li, slot)) {
            Some(a) => a,
            None => continue,
        };
        // gather every later span resolving to this same adapter
        let mut rows: Vec<(usize, usize)> = vec![(spans[i].0, spans[i].1)];
        let mut total = spans[i].1;
        for j in (i + 1)..spans.len() {
            if done[j] {
                continue;
            }
            if let Some(aj) = spans[j].2.and_then(|s| s.get(li, slot)) {
                if std::ptr::eq(ad, aj) {
                    done[j] = true;
                    rows.push((spans[j].0, spans[j].1));
                    total += spans[j].1;
                }
            }
        }
        let whole = total == n_rows
            && rows.first().map(|r| r.0) == Some(0)
            && rows.windows(2).all(|w| w[0].0 + w[0].1 == w[1].0);
        let low = if whole {
            x.matmul(&ad.a)?.matmul(&ad.b_t)?
        } else {
            let mut xg = Tensor::zeros(&[total, d_in]);
            {
                let xd = x.data();
                let gd = xg.data_mut();
                let mut w = 0usize;
                for &(r0, n) in &rows {
                    gd[w * d_in..(w + n) * d_in].copy_from_slice(&xd[r0 * d_in..(r0 + n) * d_in]);
                    w += n;
                }
            }
            xg.matmul(&ad.a)?.matmul(&ad.b_t)?
        };
        // scatter `y += scale·low` then DoRA's column rescale, per row in
        // the exact operation order of the single-adapter path
        let ld = low.data();
        let yd = y.data_mut();
        let mut w = 0usize;
        for &(r0, n) in &rows {
            for r in 0..n {
                let yrow = &mut yd[(r0 + r) * d_out..(r0 + r + 1) * d_out];
                let lrow = &ld[(w + r) * d_out..(w + r + 1) * d_out];
                for (v, &lv) in yrow.iter_mut().zip(lrow) {
                    *v += ad.scale * lv;
                }
                if let Some(cs) = &ad.col_scale {
                    for (v, &c) in yrow.iter_mut().zip(cs.iter()) {
                        *v *= c;
                    }
                }
            }
            w += n;
        }
    }
    Ok(())
}

impl PackedModel {
    /// Embed a flat token slice into (n, d), with the same out-of-vocab
    /// clamp as `PackedModel::logits`.
    fn embed_rows(&self, tokens: &[i32]) -> Tensor {
        let d = self.cfg.d_model;
        let vocab = self.cfg.vocab;
        let mut x = Tensor::zeros(&[tokens.len(), d]);
        let xd = x.data_mut();
        for (i, &tok) in tokens.iter().enumerate() {
            let tok = (tok.max(0) as usize).min(vocab - 1);
            xd[i * d..(i + 1) * d].copy_from_slice(self.embed.row(tok));
        }
        x
    }

    /// Final norm + LM head over hidden states (n, d) -> logits (n, vocab).
    fn head(&self, mut x: Tensor) -> Result<Tensor> {
        rmsnorm_rows(x.data_mut(), self.cfg.d_model, self.final_norm.data());
        x.matmul(&self.lm_head)
    }

    /// Forward the next `t` positions of ONE sequence, appending K/V for
    /// every layer to `cache` and committing `t` positions on success.
    /// With an empty cache this is prefill; with a warm cache it extends
    /// the sequence.  Returns the chunk logits `(t, vocab)`.  Applies the
    /// model's default adapter set; route another via
    /// [`PackedModel::forward_chunk_with`].
    pub fn forward_chunk(&self, tokens: &[i32], cache: &mut KvCache) -> Result<Tensor> {
        self.forward_chunk_with(tokens, cache, self.default_adapter.as_deref())
    }

    /// [`PackedModel::forward_chunk`] with an explicit adapter set
    /// (`None` = frozen base only).
    pub fn forward_chunk_with(
        &self,
        tokens: &[i32],
        cache: &mut KvCache,
        set: Option<&AdapterSet>,
    ) -> Result<Tensor> {
        let t = tokens.len();
        if t == 0 {
            return Err(Error::shape("forward_chunk: empty token chunk"));
        }
        cache.check_shape(self.cfg.n_layers, self.cfg.d_model)?;
        if cache.remaining() < t {
            return Err(Error::shape(format!(
                "forward_chunk: {} cached + {t} new > capacity {}",
                cache.len(),
                cache.capacity()
            )));
        }
        let hd = self.cfg.d_model / self.cfg.n_heads;
        let p0 = cache.len();
        let tables = self.rope.upto(hd, p0 + t);
        let rope = tables.view(p0, t);
        let spans = [(0usize, t, set)];
        let mut x = self.embed_rows(tokens);
        for (li, block) in self.blocks.iter().enumerate() {
            x = block_forward_chunk(block, self, &x, t, p0, &rope, cache, li, &spans)?;
        }
        cache.advance(t);
        self.head(x)
    }

    /// One decode step for a batch of independent sequences: `tokens[i]`
    /// is the newest token of sequence `i`, `caches[i]` its KV cache
    /// (positions may differ per sequence — that is what lets the
    /// continuous-batching scheduler mix mid-flight requests).  Appends
    /// one position to every cache and returns logits `(b, vocab)`.
    /// Applies the model's default adapter set to every sequence; route
    /// per-sequence sets via [`PackedModel::forward_step_with`].
    pub fn forward_step(&self, tokens: &[i32], caches: &mut [&mut KvCache]) -> Result<Tensor> {
        let sets = vec![self.default_adapter.as_deref(); tokens.len()];
        self.forward_step_with(tokens, caches, &sets)
    }

    /// [`PackedModel::forward_step`] with one adapter set per sequence:
    /// the shared fused base GEMV runs ONCE across all sequences in the
    /// step, then each sequence's low-rank delta is applied, grouped by
    /// adapter identity.
    pub fn forward_step_with(
        &self,
        tokens: &[i32],
        caches: &mut [&mut KvCache],
        adapters: &[Option<&AdapterSet>],
    ) -> Result<Tensor> {
        let b = tokens.len();
        if b == 0 || b != caches.len() || b != adapters.len() {
            return Err(Error::shape(format!(
                "forward_step: {b} tokens vs {} caches vs {} adapters",
                caches.len(),
                adapters.len()
            )));
        }
        let d = self.cfg.d_model;
        let hd = d / self.cfg.n_heads;
        for c in caches.iter() {
            c.check_shape(self.cfg.n_layers, d)?;
            if c.remaining() < 1 {
                return Err(Error::shape("forward_step: a sequence's KV cache is full"));
            }
        }
        // One single-position view per sequence (positions differ) into
        // the model's precomputed RoPE table — no per-step sin/cos
        // rebuild (the tables grow once to the KV capacity and are then
        // pure indexing).
        let need = caches.iter().map(|c| c.len() + 1).max().unwrap_or(1);
        let tables = self.rope.upto(hd, need);
        let ropes: Vec<RopeView<'_>> = caches.iter().map(|c| tables.view(c.len(), 1)).collect();
        let spans: Vec<AdapterSpan<'_>> =
            adapters.iter().enumerate().map(|(i, &s)| (i, 1, s)).collect();
        let mut x = self.embed_rows(tokens);
        for (li, block) in self.blocks.iter().enumerate() {
            x = block_forward_step(block, self, &x, &ropes, caches, li, &spans)?;
        }
        for c in caches.iter_mut() {
            c.advance(1);
        }
        self.head(x)
    }

    /// Paged twin of [`PackedModel::forward_chunk`]: same contract, but
    /// K/V land in `cache`'s block table (pages drawn from `pool` on
    /// demand, copy-on-write if a shared tail page is in the write
    /// range).  Bitwise identical to the flat path.
    pub fn forward_chunk_paged(
        &self,
        tokens: &[i32],
        cache: &mut PagedKvCache,
        pool: &mut BlockPool,
    ) -> Result<Tensor> {
        self.forward_chunk_paged_with(tokens, cache, pool, self.default_adapter.as_deref())
    }

    /// [`PackedModel::forward_chunk_paged`] with an explicit adapter set
    /// (`None` = frozen base only).
    pub fn forward_chunk_paged_with(
        &self,
        tokens: &[i32],
        cache: &mut PagedKvCache,
        pool: &mut BlockPool,
        set: Option<&AdapterSet>,
    ) -> Result<Tensor> {
        let t = tokens.len();
        if t == 0 {
            return Err(Error::shape("forward_chunk_paged: empty token chunk"));
        }
        cache.check_shape(self.cfg.n_layers, self.cfg.d_model)?;
        let p0 = cache.len();
        cache.reserve(p0 + t, pool)?;
        let hd = self.cfg.d_model / self.cfg.n_heads;
        let tables = self.rope.upto(hd, p0 + t);
        let rope = tables.view(p0, t);
        let spans = [(0usize, t, set)];
        let mut x = self.embed_rows(tokens);
        for (li, block) in self.blocks.iter().enumerate() {
            x = block_forward_chunk_paged(block, self, &x, t, p0, &rope, cache, pool, li, &spans)?;
        }
        cache.advance(t);
        self.head(x)
    }

    /// Paged twin of [`PackedModel::forward_step`]: one decode step for a
    /// batch of paged sequences, growing each block table by at most one
    /// page.  Fails with a pool-exhausted error if the block budget
    /// cannot cover a sequence's next position (the scheduler reserves
    /// per sequence beforehand so it can finish just that sequence with
    /// `capacity` instead).
    pub fn forward_step_paged(
        &self,
        tokens: &[i32],
        caches: &mut [&mut PagedKvCache],
        pool: &mut BlockPool,
    ) -> Result<Tensor> {
        let sets = vec![self.default_adapter.as_deref(); tokens.len()];
        self.forward_step_paged_with(tokens, caches, pool, &sets)
    }

    /// [`PackedModel::forward_step_paged`] with one adapter set per
    /// sequence — the batched mixed-adapter decode step: the shared fused
    /// base GEMV runs ONCE across all sequences in the tick, then each
    /// sequence's low-rank delta is applied, grouped by adapter identity
    /// so sequences on the same adapter share one delta GEMM.
    pub fn forward_step_paged_with(
        &self,
        tokens: &[i32],
        caches: &mut [&mut PagedKvCache],
        pool: &mut BlockPool,
        adapters: &[Option<&AdapterSet>],
    ) -> Result<Tensor> {
        let b = tokens.len();
        if b == 0 || b != caches.len() || b != adapters.len() {
            return Err(Error::shape(format!(
                "forward_step_paged: {b} tokens vs {} caches vs {} adapters",
                caches.len(),
                adapters.len()
            )));
        }
        let d = self.cfg.d_model;
        let hd = d / self.cfg.n_heads;
        for c in caches.iter_mut() {
            c.check_shape(self.cfg.n_layers, d)?;
            let upto = c.len() + 1;
            c.reserve(upto, pool)?;
        }
        let need = caches.iter().map(|c| c.len() + 1).max().unwrap_or(1);
        let tables = self.rope.upto(hd, need);
        let ropes: Vec<RopeView<'_>> = caches.iter().map(|c| tables.view(c.len(), 1)).collect();
        let spans: Vec<AdapterSpan<'_>> =
            adapters.iter().enumerate().map(|(i, &s)| (i, 1, s)).collect();
        let mut x = self.embed_rows(tokens);
        for (li, block) in self.blocks.iter().enumerate() {
            x = block_forward_step_paged(block, self, &x, &ropes, caches, pool, li, &spans)?;
        }
        for c in caches.iter_mut() {
            c.advance(1);
        }
        self.head(x)
    }

    /// Shared core of [`PackedModel::prefill_batch`] and
    /// [`PackedModel::forward_verify_paged`]: forward the ragged
    /// concatenation of several sequences' pending chunks (`suffixes[i]`
    /// extends `caches[i]`) in ONE pass — the linears run over all rows
    /// at once, attention per sequence — committing every new position.
    /// Returns the hidden states `(sum t_i, d)` plus the per-sequence
    /// chunk lengths.
    ///
    /// Capacity must already be [`PagedKvCache::reserve`]d; this method
    /// deliberately does NOT reserve, because re-running copy-on-write
    /// here would split block mappings that same-tick admissions share
    /// on purpose (the scheduler reserves each admission before later
    /// admissions fork from it).
    fn ragged_forward_paged(
        &self,
        suffixes: &[&[i32]],
        caches: &mut [&mut PagedKvCache],
        pool: &mut BlockPool,
        adapters: &[Option<&AdapterSet>],
    ) -> Result<(Tensor, Vec<usize>)> {
        let b = suffixes.len();
        if b == 0 || b != caches.len() || b != adapters.len() {
            return Err(Error::shape(format!(
                "ragged paged forward: {b} suffixes vs {} caches vs {} adapters",
                caches.len(),
                adapters.len()
            )));
        }
        let d = self.cfg.d_model;
        let hd = d / self.cfg.n_heads;
        let mut p0s = Vec::with_capacity(b);
        let mut ts = Vec::with_capacity(b);
        let mut need = 1usize;
        for (sfx, c) in suffixes.iter().zip(caches.iter()) {
            if sfx.is_empty() {
                return Err(Error::shape("ragged paged forward: empty suffix chunk"));
            }
            c.check_shape(self.cfg.n_layers, d)?;
            if c.capacity() < c.len() + sfx.len() {
                return Err(Error::shape(format!(
                    "ragged paged forward: {} cached + {} new > reserved capacity {} (reserve first)",
                    c.len(),
                    sfx.len(),
                    c.capacity()
                )));
            }
            p0s.push(c.len());
            ts.push(sfx.len());
            need = need.max(c.len() + sfx.len());
        }
        let flat: Vec<i32> = suffixes.iter().flat_map(|s| s.iter().copied()).collect();
        let tables = self.rope.upto(hd, need);
        let ropes: Vec<RopeView<'_>> =
            p0s.iter().zip(&ts).map(|(&p0, &t)| tables.view(p0, t)).collect();
        let mut spans: Vec<AdapterSpan<'_>> = Vec::with_capacity(b);
        {
            let mut row = 0usize;
            for (&t, &set) in ts.iter().zip(adapters.iter()) {
                spans.push((row, t, set));
                row += t;
            }
        }
        let mut x = self.embed_rows(&flat);
        for (li, block) in self.blocks.iter().enumerate() {
            x = block_prefill_batch(block, self, &x, &p0s, &ts, &ropes, caches, pool, li, &spans)?;
        }
        for (c, &t) in caches.iter_mut().zip(&ts) {
            c.advance(t);
        }
        Ok((x, ts))
    }

    /// ONE batched prefill pass over several sequences' pending chunks
    /// (`suffixes[i]` extends `caches[i]`, whose committed prefix may be
    /// empty, warm, or prefix-shared).  The linears run over the ragged
    /// row concatenation — one batched GEMM per projection instead of
    /// one per sequence — and attention runs per sequence.  Returns the
    /// **last-position** logits `(b, vocab)`, i.e. each request's
    /// first-token distribution.  Capacity must already be
    /// [`PagedKvCache::reserve`]d (see the ragged core above).
    pub fn prefill_batch(
        &self,
        suffixes: &[&[i32]],
        caches: &mut [&mut PagedKvCache],
        pool: &mut BlockPool,
    ) -> Result<Tensor> {
        let sets = vec![self.default_adapter.as_deref(); suffixes.len()];
        self.prefill_batch_with(suffixes, caches, pool, &sets)
    }

    /// [`PackedModel::prefill_batch`] with one adapter set per sequence.
    pub fn prefill_batch_with(
        &self,
        suffixes: &[&[i32]],
        caches: &mut [&mut PagedKvCache],
        pool: &mut BlockPool,
        adapters: &[Option<&AdapterSet>],
    ) -> Result<Tensor> {
        let (x, ts) = self.ragged_forward_paged(suffixes, caches, pool, adapters)?;
        let b = ts.len();
        let d = self.cfg.d_model;
        // Gather each sequence's last hidden row; head() is row-wise, so
        // running it on just these rows matches the full-chunk head bit
        // for bit at those positions.
        let mut last = Tensor::zeros(&[b, d]);
        {
            let ld = last.data_mut();
            let xd = x.data();
            let mut row = 0usize;
            for (bi, &t) in ts.iter().enumerate() {
                row += t;
                ld[bi * d..(bi + 1) * d].copy_from_slice(&xd[(row - 1) * d..row * d]);
            }
        }
        self.head(last)
    }

    /// Speculative-verify forward: the same ragged batched pass as
    /// [`PackedModel::prefill_batch`], but surfacing logits at **every**
    /// position — `suffixes[i]` is sequence `i`'s `k_i + 1`-token chunk
    /// `[newest emitted token, draft_1, ..., draft_k]`, and row `j` of
    /// its slice is the target's next-token distribution after consuming
    /// the first `j + 1` chunk tokens, bitwise identical to what `k_i+1`
    /// sequential [`PackedModel::forward_step_paged`] calls would have
    /// produced.  Returns `(sum (k_i + 1), vocab)`; row offsets are the
    /// prefix sums of the chunk lengths.  Rejected positions are popped
    /// afterwards with [`PagedKvCache::truncate`].  Same reserve
    /// contract as `prefill_batch`.
    pub fn forward_verify_paged(
        &self,
        suffixes: &[&[i32]],
        caches: &mut [&mut PagedKvCache],
        pool: &mut BlockPool,
    ) -> Result<Tensor> {
        let sets = vec![self.default_adapter.as_deref(); suffixes.len()];
        self.forward_verify_paged_with(suffixes, caches, pool, &sets)
    }

    /// [`PackedModel::forward_verify_paged`] with one adapter set per
    /// sequence.
    pub fn forward_verify_paged_with(
        &self,
        suffixes: &[&[i32]],
        caches: &mut [&mut PagedKvCache],
        pool: &mut BlockPool,
        adapters: &[Option<&AdapterSet>],
    ) -> Result<Tensor> {
        let (x, _ts) = self.ragged_forward_paged(suffixes, caches, pool, adapters)?;
        self.head(x)
    }
}

/// Caller-owned scratch for [`attend_segs`]: the score/prob buffer plus
/// a head-slice dequant buffer for quantized segments.  Hoisted by the
/// batched paths so the hot loop never heap-allocates per sequence per
/// layer.
#[derive(Default)]
struct AttendScratch {
    probs: Vec<f32>,
    row: Vec<f32>,
}

/// The attention core shared by every cached path: causal attention of
/// `t` chunk queries against key/value rows `[0, p0 + t)` presented as a
/// list of contiguous [`KvSegment`]s in ascending position order.  The
/// flat layout passes one f32 segment; the paged layout passes one per
/// block — staged pages as f32 rows, sealed pages as quantized views
/// dequantized on the fly (fused dequant attention).  Scores are filled,
/// the running max tracked, the softmax normalized, and values
/// accumulated position-by-position in the exact same order either way,
/// so segmentation never changes a bit of the output.
///
/// Quantized segments keep the determinism contract: the K head slice is
/// dequantized into scratch through [`kv_row_dequant`] (scalar and AVX2
/// bitwise identical) and dotted in the same ascending-`j` scalar loop
/// the f32 path uses; value rows accumulate through [`kv_row_accum`]
/// with the f32 path's exact per-lane `ctx[j] + pw * v` order.
#[allow(clippy::too_many_arguments)]
fn attend_segs(
    qd: &[f32],
    segs: &[KvSegment<'_>],
    ctx: &mut [f32],
    t: usize,
    p0: usize,
    h: usize,
    hd: usize,
    scratch: &mut AttendScratch,
) {
    let d = h * hd;
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    let kernel = kernels::active();
    let AttendScratch { probs, row } = scratch;
    probs.resize(p0 + t, 0.0);
    row.resize(hd, 0.0);
    for head in 0..h {
        let off = head * hd;
        for tq in 0..t {
            let klen = p0 + tq + 1;
            let qrow = &qd[tq * d + off..tq * d + off + hd];
            let mut mx = f32::NEG_INFINITY;
            let mut pos = 0usize;
            'score: for seg in segs {
                match seg {
                    KvSegment::F32(kc, _) => {
                        for krow in kc.chunks_exact(d) {
                            if pos >= klen {
                                break 'score;
                            }
                            let krow = &krow[off..off + hd];
                            let mut s = 0.0f32;
                            for j in 0..hd {
                                s += qrow[j] * krow[j];
                            }
                            let s = s * inv_sqrt;
                            probs[pos] = s;
                            mx = mx.max(s);
                            pos += 1;
                        }
                    }
                    KvSegment::Quant { k, rows, .. } => {
                        for r in 0..*rows {
                            if pos >= klen {
                                break 'score;
                            }
                            kv_row_dequant(kernel, k, r * d + off, &mut row[..hd]);
                            let mut s = 0.0f32;
                            for j in 0..hd {
                                s += qrow[j] * row[j];
                            }
                            let s = s * inv_sqrt;
                            probs[pos] = s;
                            mx = mx.max(s);
                            pos += 1;
                        }
                    }
                }
            }
            debug_assert!(pos >= klen, "segments must cover the attention span");
            let mut denom = 0.0f32;
            for p in probs.iter_mut().take(klen) {
                *p = (*p - mx).exp();
                denom += *p;
            }
            let inv = 1.0 / denom;
            let c0 = tq * d + off;
            let mut pos = 0usize;
            'acc: for seg in segs {
                match seg {
                    KvSegment::F32(_, vc) => {
                        for vrow in vc.chunks_exact(d) {
                            if pos >= klen {
                                break 'acc;
                            }
                            let pw = probs[pos] * inv;
                            let vrow = &vrow[off..off + hd];
                            let crow = &mut ctx[c0..c0 + hd];
                            for j in 0..hd {
                                crow[j] += pw * vrow[j];
                            }
                            pos += 1;
                        }
                    }
                    KvSegment::Quant { v, rows, .. } => {
                        for r in 0..*rows {
                            if pos >= klen {
                                break 'acc;
                            }
                            let pw = probs[pos] * inv;
                            kv_row_accum(kernel, v, r * d + off, pw, &mut ctx[c0..c0 + hd]);
                            pos += 1;
                        }
                    }
                }
            }
        }
    }
}

/// SwiGLU FFN branch shared by chunk and step paths: x1 + Wdown(silu(Wgate(norm(x1))) * Wup(norm(x1))).
fn ffn_branch(
    block: &PackedBlock,
    d: usize,
    x1: &Tensor,
    li: usize,
    spans: &[AdapterSpan<'_>],
) -> Result<Tensor> {
    let mut ffn_in = x1.clone();
    rmsnorm_rows(ffn_in.data_mut(), d, block.ffn_norm.data());
    let mut hidden = block.wgate.forward(&ffn_in, None)?;
    apply_adapter_deltas(&mut hidden, &ffn_in, spans, li, SLOT_WGATE)?;
    let mut up = block.wup.forward(&ffn_in, None)?;
    apply_adapter_deltas(&mut up, &ffn_in, spans, li, SLOT_WUP)?;
    for (g, &u) in hidden.data_mut().iter_mut().zip(up.data()) {
        let gv = *g;
        *g = gv / (1.0 + (-gv).exp()) * u; // silu(gate) * up
    }
    let mut ffn_out = block.wdown.forward(&hidden, None)?;
    apply_adapter_deltas(&mut ffn_out, &hidden, spans, li, SLOT_WDOWN)?;
    x1.add(&ffn_out)
}

/// Q/K/V projections over the (possibly batched) normalized input: one
/// shared base GEMM each across every row, then per-sequence adapter
/// deltas grouped by adapter identity.
fn qkv_project(
    block: &PackedBlock,
    attn_in: &Tensor,
    li: usize,
    spans: &[AdapterSpan<'_>],
) -> Result<(Tensor, Tensor, Tensor)> {
    let mut q = block.wq.forward(attn_in, None)?;
    apply_adapter_deltas(&mut q, attn_in, spans, li, SLOT_WQ)?;
    let mut k = block.wk.forward(attn_in, None)?;
    apply_adapter_deltas(&mut k, attn_in, spans, li, SLOT_WK)?;
    let mut v = block.wv.forward(attn_in, None)?;
    apply_adapter_deltas(&mut v, attn_in, spans, li, SLOT_WV)?;
    Ok((q, k, v))
}

/// Output projection over the attention context, base + adapter deltas.
fn out_project(
    block: &PackedBlock,
    ctx: &Tensor,
    li: usize,
    spans: &[AdapterSpan<'_>],
) -> Result<Tensor> {
    let mut attn_out = block.wo.forward(ctx, None)?;
    apply_adapter_deltas(&mut attn_out, ctx, spans, li, SLOT_WO)?;
    Ok(attn_out)
}

/// One block over a single sequence's chunk x (t, d), reading/writing
/// layer `li` of `cache` (chunk K/V rows land at positions p0..p0+t).
#[allow(clippy::too_many_arguments)]
fn block_forward_chunk(
    block: &PackedBlock,
    model: &PackedModel,
    x: &Tensor,
    t: usize,
    p0: usize,
    rope: &RopeView<'_>,
    cache: &mut KvCache,
    li: usize,
    spans: &[AdapterSpan<'_>],
) -> Result<Tensor> {
    let d = model.cfg.d_model;
    let h = model.cfg.n_heads;
    let hd = d / h;

    // -- attention branch --
    let mut attn_in = x.clone();
    rmsnorm_rows(attn_in.data_mut(), d, block.attn_norm.data());
    let (mut q, mut k, v) = qkv_project(block, &attn_in, li, spans)?;
    apply_rope(q.data_mut(), 1, t, h, hd, rope);
    apply_rope(k.data_mut(), 1, t, h, hd, rope);
    cache.write_rows(li, k.data(), v.data())?;

    let mut ctx = Tensor::zeros(&[t, d]);
    let mut scratch = AttendScratch::default();
    attend_segs(
        q.data(),
        &[KvSegment::F32(cache.keys(li, p0 + t), cache.values(li, p0 + t))],
        ctx.data_mut(),
        t,
        p0,
        h,
        hd,
        &mut scratch,
    );
    let attn_out = out_project(block, &ctx, li, spans)?;
    let x1 = x.add(&attn_out)?;

    ffn_branch(block, d, &x1, li, spans)
}

/// Paged twin of [`block_forward_chunk`]: K/V rows scatter into the
/// sequence's block table; attention walks the per-page segments.
#[allow(clippy::too_many_arguments)]
fn block_forward_chunk_paged(
    block: &PackedBlock,
    model: &PackedModel,
    x: &Tensor,
    t: usize,
    p0: usize,
    rope: &RopeView<'_>,
    cache: &mut PagedKvCache,
    pool: &mut BlockPool,
    li: usize,
    spans: &[AdapterSpan<'_>],
) -> Result<Tensor> {
    let d = model.cfg.d_model;
    let h = model.cfg.n_heads;
    let hd = d / h;

    let mut attn_in = x.clone();
    rmsnorm_rows(attn_in.data_mut(), d, block.attn_norm.data());
    let (mut q, mut k, v) = qkv_project(block, &attn_in, li, spans)?;
    apply_rope(q.data_mut(), 1, t, h, hd, rope);
    apply_rope(k.data_mut(), 1, t, h, hd, rope);
    cache.write_rows(pool, li, k.data(), v.data())?;

    let mut ctx = Tensor::zeros(&[t, d]);
    let mut scratch = AttendScratch::default();
    let mut segs = Vec::new();
    let pool_ref: &BlockPool = pool;
    cache.segments_into(pool_ref, li, p0 + t, &mut segs);
    attend_segs(q.data(), &segs, ctx.data_mut(), t, p0, h, hd, &mut scratch);
    let attn_out = out_project(block, &ctx, li, spans)?;
    let x1 = x.add(&attn_out)?;

    ffn_branch(block, d, &x1, li, spans)
}

/// One block over a batch of single newest positions x (b, d): linears
/// run batched, attention per sequence against its own cache.
#[allow(clippy::too_many_arguments)]
fn block_forward_step(
    block: &PackedBlock,
    model: &PackedModel,
    x: &Tensor,
    ropes: &[RopeView<'_>],
    caches: &mut [&mut KvCache],
    li: usize,
    spans: &[AdapterSpan<'_>],
) -> Result<Tensor> {
    let d = model.cfg.d_model;
    let h = model.cfg.n_heads;
    let hd = d / h;
    let b = x.rows();

    // -- attention branch (projections batched across sequences) --
    let mut attn_in = x.clone();
    rmsnorm_rows(attn_in.data_mut(), d, block.attn_norm.data());
    let (mut q, mut k, v) = qkv_project(block, &attn_in, li, spans)?;
    for bi in 0..b {
        apply_rope(&mut q.data_mut()[bi * d..(bi + 1) * d], 1, 1, h, hd, &ropes[bi]);
        apply_rope(&mut k.data_mut()[bi * d..(bi + 1) * d], 1, 1, h, hd, &ropes[bi]);
        let krow = &k.data()[bi * d..(bi + 1) * d];
        let vrow = &v.data()[bi * d..(bi + 1) * d];
        caches[bi].write_rows(li, krow, vrow)?;
    }

    let mut ctx = Tensor::zeros(&[b, d]);
    {
        let cd = ctx.data_mut();
        let qd = q.data();
        let mut scratch = AttendScratch::default();
        for (bi, cache) in caches.iter().enumerate() {
            let klen = cache.len() + 1; // cached prefix + the row just written
            attend_segs(
                &qd[bi * d..(bi + 1) * d],
                &[KvSegment::F32(cache.keys(li, klen), cache.values(li, klen))],
                &mut cd[bi * d..(bi + 1) * d],
                1,
                klen - 1,
                h,
                hd,
                &mut scratch,
            );
        }
    }
    let attn_out = out_project(block, &ctx, li, spans)?;
    let x1 = x.add(&attn_out)?;

    ffn_branch(block, d, &x1, li, spans)
}

/// Paged twin of [`block_forward_step`].
#[allow(clippy::too_many_arguments)]
fn block_forward_step_paged(
    block: &PackedBlock,
    model: &PackedModel,
    x: &Tensor,
    ropes: &[RopeView<'_>],
    caches: &mut [&mut PagedKvCache],
    pool: &mut BlockPool,
    li: usize,
    spans: &[AdapterSpan<'_>],
) -> Result<Tensor> {
    let d = model.cfg.d_model;
    let h = model.cfg.n_heads;
    let hd = d / h;
    let b = x.rows();

    let mut attn_in = x.clone();
    rmsnorm_rows(attn_in.data_mut(), d, block.attn_norm.data());
    let (mut q, mut k, v) = qkv_project(block, &attn_in, li, spans)?;
    for bi in 0..b {
        apply_rope(&mut q.data_mut()[bi * d..(bi + 1) * d], 1, 1, h, hd, &ropes[bi]);
        apply_rope(&mut k.data_mut()[bi * d..(bi + 1) * d], 1, 1, h, hd, &ropes[bi]);
        let krow = &k.data()[bi * d..(bi + 1) * d];
        let vrow = &v.data()[bi * d..(bi + 1) * d];
        caches[bi].write_rows(&mut *pool, li, krow, vrow)?;
    }

    let mut ctx = Tensor::zeros(&[b, d]);
    {
        let cd = ctx.data_mut();
        let qd = q.data();
        let mut scratch = AttendScratch::default();
        let mut segs = Vec::new();
        let pool_ref: &BlockPool = pool;
        for (bi, cache) in caches.iter().enumerate() {
            let klen = cache.len() + 1; // cached prefix + the row just written
            cache.segments_into(pool_ref, li, klen, &mut segs);
            attend_segs(
                &qd[bi * d..(bi + 1) * d],
                &segs,
                &mut cd[bi * d..(bi + 1) * d],
                1,
                klen - 1,
                h,
                hd,
                &mut scratch,
            );
        }
    }
    let attn_out = out_project(block, &ctx, li, spans)?;
    let x1 = x.add(&attn_out)?;

    ffn_branch(block, d, &x1, li, spans)
}

/// One block of the batched prefill: x is the ragged concatenation of
/// every sequence's chunk rows (`ts[bi]` rows each, sequence `bi`
/// extending committed prefix `p0s[bi]`).  Projections run over all
/// rows at once; every sequence's K/V rows are written before ANY
/// sequence attends, so same-tick prefix sharing reads rows
/// materialized earlier in this very pass.
#[allow(clippy::too_many_arguments)]
fn block_prefill_batch(
    block: &PackedBlock,
    model: &PackedModel,
    x: &Tensor,
    p0s: &[usize],
    ts: &[usize],
    ropes: &[RopeView<'_>],
    caches: &mut [&mut PagedKvCache],
    pool: &mut BlockPool,
    li: usize,
    spans: &[AdapterSpan<'_>],
) -> Result<Tensor> {
    let d = model.cfg.d_model;
    let h = model.cfg.n_heads;
    let hd = d / h;

    let mut attn_in = x.clone();
    rmsnorm_rows(attn_in.data_mut(), d, block.attn_norm.data());
    let (mut q, mut k, v) = qkv_project(block, &attn_in, li, spans)?;
    let mut row = 0usize;
    for (bi, &t) in ts.iter().enumerate() {
        let span = row * d..(row + t) * d;
        apply_rope(&mut q.data_mut()[span.clone()], 1, t, h, hd, &ropes[bi]);
        apply_rope(&mut k.data_mut()[span.clone()], 1, t, h, hd, &ropes[bi]);
        caches[bi].write_rows(&mut *pool, li, &k.data()[span.clone()], &v.data()[span])?;
        row += t;
    }

    let n = x.rows();
    let mut ctx = Tensor::zeros(&[n, d]);
    {
        let cd = ctx.data_mut();
        let qd = q.data();
        let mut scratch = AttendScratch::default();
        let mut segs = Vec::new();
        let pool_ref: &BlockPool = pool;
        let mut row = 0usize;
        for (bi, &t) in ts.iter().enumerate() {
            caches[bi].segments_into(pool_ref, li, p0s[bi] + t, &mut segs);
            attend_segs(
                &qd[row * d..(row + t) * d],
                &segs,
                &mut cd[row * d..(row + t) * d],
                t,
                p0s[bi],
                h,
                hd,
                &mut scratch,
            );
            row += t;
        }
    }
    let attn_out = out_project(block, &ctx, li, spans)?;
    let x1 = x.add(&attn_out)?;

    ffn_branch(block, d, &x1, li, spans)
}

/// Pick the next token from a logits row: seeded sampling when params and
/// an rng stream are present, greedy argmax otherwise.  Shared with the
/// scheduler so batched serving picks tokens exactly like `generate`.
pub(crate) fn pick(row: &[f32], sampling: Option<&SamplingParams>, rng: Option<&mut Rng>) -> i32 {
    match (sampling, rng) {
        (Some(p), Some(r)) => sample(row, p, r) as i32,
        _ => argmax(row) as i32,
    }
}

fn check_prompt(prompt: &IntTensor) -> Result<(usize, usize)> {
    if prompt.shape().len() != 2 || prompt.shape()[0] == 0 || prompt.shape()[1] == 0 {
        return Err(Error::shape("generate wants a non-empty (B, T0) prompt"));
    }
    Ok((prompt.shape()[0], prompt.shape()[1]))
}

/// Batched KV-cached decoding: extend `prompt` (B, T0) by `max_new`
/// tokens — greedy argmax when `sampling` is `None`, seeded
/// temperature/top-k/top-p sampling otherwise (sequence `i` draws from
/// the independent stream `seq_rng(params.seed, i)`, so runs are
/// reproducible and batch order doesn't leak between sequences).
pub fn generate(
    model: &PackedModel,
    prompt: &IntTensor,
    max_new: usize,
    sampling: Option<&SamplingParams>,
) -> Result<GenReport> {
    let (b, t0) = check_prompt(prompt)?;
    let cfg = &model.cfg;
    let mut rows: Vec<Vec<i32>> = (0..b)
        .map(|i| prompt.data()[i * t0..(i + 1) * t0].to_vec())
        .collect();
    let mut rngs: Vec<Option<Rng>> = (0..b)
        .map(|i| sampling.map(|p| seq_rng(p.seed, i)))
        .collect();
    let start = Instant::now();
    if max_new > 0 {
        let mut caches: Vec<KvCache> = (0..b)
            .map(|_| KvCache::new(cfg.n_layers, cfg.d_model, t0 + max_new))
            .collect();
        // prefill each sequence and emit its first token
        for (bi, row) in rows.iter_mut().enumerate() {
            let logits = model.forward_chunk(&row[..], &mut caches[bi])?;
            let tok = pick(logits.row(t0 - 1), sampling, rngs[bi].as_mut());
            row.push(tok);
        }
        // incremental steps: only the newest token column is materialized
        for _ in 1..max_new {
            let newest: Vec<i32> = rows.iter().map(|r| *r.last().unwrap()).collect();
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            let logits = model.forward_step(&newest, &mut refs)?;
            for (bi, row) in rows.iter_mut().enumerate() {
                let tok = pick(logits.row(bi), sampling, rngs[bi].as_mut());
                row.push(tok);
            }
        }
    }
    Ok(GenReport {
        tokens: rows,
        prompt_len: t0,
        new_tokens: max_new,
        wall_secs: start.elapsed().as_secs_f64(),
    })
}

/// [`generate`] over paged KV storage: same decode loop, but each
/// sequence holds a block table over a run-local [`BlockPool`] of
/// `block_size`-position pages instead of a worst-case flat slab.
/// Token streams are bitwise identical to [`generate`] at every block
/// size (`tests/paged.rs` pins sizes 1 / 7 / 64).
pub fn generate_paged(
    model: &PackedModel,
    prompt: &IntTensor,
    max_new: usize,
    sampling: Option<&SamplingParams>,
    block_size: usize,
) -> Result<GenReport> {
    let (b, t0) = check_prompt(prompt)?;
    let cfg = &model.cfg;
    let mut rows: Vec<Vec<i32>> = (0..b)
        .map(|i| prompt.data()[i * t0..(i + 1) * t0].to_vec())
        .collect();
    let mut rngs: Vec<Option<Rng>> = (0..b)
        .map(|i| sampling.map(|p| seq_rng(p.seed, i)))
        .collect();
    let start = Instant::now();
    if max_new > 0 {
        let bs = block_size.max(1);
        let per_seq = (t0 + max_new).div_ceil(bs);
        let mut pool = BlockPool::new(cfg.n_layers, cfg.d_model, bs, b * per_seq);
        let mut caches: Vec<PagedKvCache> = (0..b).map(|_| PagedKvCache::new(&pool)).collect();
        for (bi, row) in rows.iter_mut().enumerate() {
            let logits = model.forward_chunk_paged(&row[..], &mut caches[bi], &mut pool)?;
            let tok = pick(logits.row(t0 - 1), sampling, rngs[bi].as_mut());
            row.push(tok);
        }
        for _ in 1..max_new {
            let newest: Vec<i32> = rows.iter().map(|r| *r.last().unwrap()).collect();
            let mut refs: Vec<&mut PagedKvCache> = caches.iter_mut().collect();
            let logits = model.forward_step_paged(&newest, &mut refs, &mut pool)?;
            for (bi, row) in rows.iter_mut().enumerate() {
                let tok = pick(logits.row(bi), sampling, rngs[bi].as_mut());
                row.push(tok);
            }
        }
    }
    Ok(GenReport {
        tokens: rows,
        prompt_len: t0,
        new_tokens: max_new,
        wall_secs: start.elapsed().as_secs_f64(),
    })
}

/// PR 1's full-prefix recompute decode (O(T^2)), kept as the equivalence
/// reference for the cached path and as the benchmark baseline.  Consumes
/// the same per-sequence rng streams as [`generate`], so seeded sampling
/// runs are comparable token for token.
pub fn generate_recompute(
    model: &PackedModel,
    prompt: &IntTensor,
    max_new: usize,
    sampling: Option<&SamplingParams>,
) -> Result<GenReport> {
    let (b, t0) = check_prompt(prompt)?;
    let vocab = model.cfg.vocab;
    let mut rows: Vec<Vec<i32>> = (0..b)
        .map(|i| prompt.data()[i * t0..(i + 1) * t0].to_vec())
        .collect();
    let mut rngs: Vec<Option<Rng>> = (0..b)
        .map(|i| sampling.map(|p| seq_rng(p.seed, i)))
        .collect();
    let start = Instant::now();
    for _ in 0..max_new {
        let cur = rows[0].len();
        let flat: Vec<i32> = rows.iter().flat_map(|r| r.iter().copied()).collect();
        let toks = IntTensor::new(vec![b, cur], flat)?;
        let logits = model.logits(&toks)?;
        let data = logits.data();
        for (bi, row) in rows.iter_mut().enumerate() {
            let last = &data[(bi * cur + cur - 1) * vocab..(bi * cur + cur) * vocab];
            row.push(pick(last, sampling, rngs[bi].as_mut()));
        }
    }
    Ok(GenReport {
        tokens: rows,
        prompt_len: t0,
        new_tokens: max_new,
        wall_secs: start.elapsed().as_secs_f64(),
    })
}
