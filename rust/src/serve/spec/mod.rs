//! Speculative decoding: a cheap draft proposes, the target verifies.
//!
//! The 2-bit serving regime is exactly where speculation pays off:
//! decode latency is dominated by one-token-at-a-time GEMVs streaming
//! the packed weights, while a k+1-token verify chunk streams them ONCE
//! for k+1 positions.  A small draft model (the same checkpoint cut to
//! its first N layers via [`crate::infer::PackedModel::prefix_cut`], or
//! any packed checkpoint sharing the vocabulary) proposes `k` greedy
//! tokens per cycle; the target verifies them in one multi-position
//! [`crate::infer::PackedModel::forward_verify_paged`] pass and accepts
//! the longest prefix it agrees with.
//!
//! ## Bit-exact acceptance
//!
//! The verify chunk's logits rows are bitwise identical to what
//! sequential `forward_step_paged` calls would have produced (the
//! kernels are bitwise row-stable across batch shapes and the paged
//! attention core walks the same segments in the same order — the
//! equivalence chain `tests/serve.rs` / `tests/paged.rs` pins).  The
//! acceptance loop therefore emits, at every position, *the target's own
//! pick from its own logits*:
//!
//! * **greedy** — accept while `draft_token == argmax(target_logits)`;
//!   on the first mismatch the target's argmax is emitted as the
//!   correction.
//! * **seeded sampling** — walk the request's rng stream one draw per
//!   emitted token (never for positions past a rejection) and accept
//!   while the draft token equals the target's sampled pick; the
//!   mismatch draw is itself the emitted correction.
//!
//! Either way the emitted stream is **bitwise identical** to
//! non-speculative decode (`tests/spec.rs`); speculation only changes
//! how many forward passes it took to produce it.
//!
//! ## KV rollback
//!
//! Verifying writes k+1 positions into the target's paged cache; the
//! rejected tail is popped with [`crate::serve::paged::PagedKvCache::truncate`],
//! which releases emptied tail pages refcount-aware (a page shared with
//! a forked sequence is dropped from the table, never scrubbed).  The
//! draft keeps its own [`BlockPool`] — draft KV never competes with
//! target KV for the serving budget and is reported separately in the
//! stats frame.

use std::collections::VecDeque;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::infer::{argmax, GenReport, PackedModel};
use crate::serve::block::{BlockPool, KvStats};
use crate::serve::decode::pick;
use crate::serve::paged::PagedKvCache;
use crate::serve::sampling::{seq_rng, SamplingParams};
use crate::tensor::{IntTensor, Rng, Tensor};

/// Cycles of rolling-acceptance history per sequence.
pub const ACCEPT_WINDOW: usize = 8;

/// A sequence whose rolling acceptance drops below this over a full
/// window stops speculating (the draft costs more than it saves).
pub const MIN_ACCEPT: f64 = 0.125;

/// Pool-wide speculative counters (rendered into the stats frame).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecCounters {
    /// Draft tokens proposed across all sequences.
    pub proposed: usize,
    /// Proposals the target accepted.
    pub accepted: usize,
    /// Draft/verify cycles run.
    pub cycles: usize,
    /// Sequences that fell back to plain decode (draft pool exhausted or
    /// acceptance collapsed).
    pub fallbacks: usize,
}

/// Snapshot of the speculative subsystem for the `{"cmd":"stats"}` frame.
#[derive(Clone, Copy, Debug)]
pub struct SpecStats {
    /// Draft tokens proposed per cycle (`--speculate`).
    pub k: usize,
    pub proposed: usize,
    pub accepted: usize,
    pub cycles: usize,
    pub fallbacks: usize,
    /// Draft-side KV pool accounting (separate budget from target KV).
    pub draft_kv: KvStats,
}

impl SpecStats {
    /// Accepted fraction of proposed draft tokens; 0.0 before any
    /// proposal (nothing drafted reads as nothing accepted, never as
    /// vacuously-perfect speculation — the collapse fallback has its
    /// own windowed counters and never consults this).
    pub fn acceptance(&self) -> f64 {
        if self.proposed == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.proposed as f64
    }
}

/// The scheduler-owned draft side of the engine: the draft model plus
/// the pool its per-sequence KV pages come from.
pub struct SpecEngine {
    pub draft: std::sync::Arc<PackedModel>,
    pub pool: BlockPool,
    /// Draft tokens per cycle.
    pub k: usize,
    pub counters: SpecCounters,
}

/// One sequence's draft-side state: its own paged KV over the draft
/// pool plus a rolling acceptance window for the collapse fallback.
pub struct DraftState {
    pub cache: PagedKvCache,
    /// Set when this sequence stopped speculating (draft pool exhausted
    /// or acceptance collapsed); plain decode takes over for good.
    pub disabled: bool,
    /// (proposed, accepted) per recent cycle, capped at [`ACCEPT_WINDOW`].
    window: VecDeque<(u32, u32)>,
}

impl DraftState {
    pub fn new(pool: &BlockPool) -> Self {
        DraftState { cache: PagedKvCache::new(pool), disabled: false, window: VecDeque::new() }
    }

    /// Record one draft/verify cycle's outcome.
    pub fn note_cycle(&mut self, proposed: usize, accepted: usize) {
        if self.window.len() == ACCEPT_WINDOW {
            self.window.pop_front();
        }
        self.window.push_back((proposed as u32, accepted as u32));
    }

    /// Rolling acceptance below [`MIN_ACCEPT`] over a FULL window — a
    /// short history never collapses, so warm-up misses don't disable a
    /// draft that would have found its footing.
    pub fn collapsed(&self) -> bool {
        if self.window.len() < ACCEPT_WINDOW {
            return false;
        }
        let (prop, acc) = self
            .window
            .iter()
            .fold((0u64, 0u64), |(p, a), &(cp, ca)| (p + cp as u64, a + ca as u64));
        prop > 0 && (acc as f64 / prop as f64) < MIN_ACCEPT
    }
}

/// The acceptance walk over one sequence's verify-chunk logits.
///
/// `logits` rows `row0 .. row0 + proposals.len() + 1` are the target's
/// next-token distributions after consuming the chunk prefix of that
/// length (row `row0 + j` follows `proposals[..j]`).  Emits the target's
/// own pick at every reached position — accepting while it equals the
/// draft's proposal, emitting the mismatch draw as the correction, and
/// emitting the bonus row when every proposal was accepted — so the
/// returned tokens are exactly the next tokens non-speculative decode
/// would have produced.  The rng stream advances once per emitted token
/// and never for positions past a rejection.  Stops early at `stop` or
/// after `remaining` tokens.  Returns `(emitted tokens, proposals
/// accepted)`; always emits at least one token when `remaining >= 1`.
pub fn accept_tokens(
    logits: &Tensor,
    row0: usize,
    proposals: &[i32],
    sampling: Option<&SamplingParams>,
    mut rng: Option<&mut Rng>,
    remaining: usize,
    stop: Option<i32>,
) -> (Vec<i32>, usize) {
    let k = proposals.len();
    let mut emitted = Vec::with_capacity(k + 1);
    let mut accepted = 0usize;
    for (j, &prop) in proposals.iter().chain(std::iter::once(&0)).enumerate() {
        if emitted.len() >= remaining {
            break;
        }
        let tok = pick(logits.row(row0 + j), sampling, rng.as_deref_mut());
        emitted.push(tok);
        if j < k && tok == prop {
            accepted += 1;
            if stop == Some(tok) {
                break;
            }
        } else {
            // Mismatch correction (j < k) or the bonus token (j == k):
            // either way the cycle ends with this target-picked token.
            break;
        }
    }
    (emitted, accepted)
}

/// Outcome of one speculative generation run.
pub struct SpecGenReport {
    pub gen: GenReport,
    /// Draft tokens proposed / accepted across the run.
    pub proposed: usize,
    pub accepted: usize,
    /// Wall seconds spent in draft forwards (the speculation overhead).
    pub draft_secs: f64,
}

impl SpecGenReport {
    /// Accepted fraction of proposed draft tokens; 0.0 when nothing was
    /// proposed (the `k = 0` baseline).
    pub fn acceptance(&self) -> f64 {
        if self.proposed == 0 {
            return 0.0;
        }
        self.accepted as f64 / self.proposed as f64
    }

    /// Fraction of total wall time spent drafting.
    pub fn draft_overhead(&self) -> f64 {
        if self.gen.wall_secs <= 0.0 {
            return 0.0;
        }
        self.draft_secs / self.gen.wall_secs
    }
}

/// Speculative twin of [`crate::serve::decode::generate_paged`]: extend
/// `prompt` (B, T0) by `max_new` tokens, drafting `k` greedy proposals
/// per cycle on `draft` and verifying them in one multi-position target
/// chunk.  Token streams are **bitwise identical** to
/// `generate`/`generate_paged` at every `k` and block size
/// (`tests/spec.rs`); `k = 0` degenerates to plain paged decode (the
/// verify chunk is just the newest token).  Sequence `i` draws from
/// `seq_rng(params.seed, i)` exactly like the non-speculative paths.
pub fn generate_speculative(
    target: &PackedModel,
    draft: &PackedModel,
    prompt: &IntTensor,
    max_new: usize,
    sampling: Option<&SamplingParams>,
    block_size: usize,
    k: usize,
) -> Result<SpecGenReport> {
    if prompt.shape().len() != 2 || prompt.shape()[0] == 0 || prompt.shape()[1] == 0 {
        return Err(Error::shape("generate_speculative wants a non-empty (B, T0) prompt"));
    }
    let (b, t0) = (prompt.shape()[0], prompt.shape()[1]);
    let mut rows: Vec<Vec<i32>> = (0..b)
        .map(|i| prompt.data()[i * t0..(i + 1) * t0].to_vec())
        .collect();
    let start = Instant::now();
    let mut proposed = 0usize;
    let mut accepted = 0usize;
    let mut draft_secs = 0.0f64;
    if max_new > 0 {
        let bs = block_size.max(1);
        // Worst-case span per sequence: the committed stream plus one
        // in-flight verify chunk (k proposals + the bonus position).
        let per_seq = (t0 + max_new + k + 1).div_ceil(bs) + 1;
        let tcfg = &target.cfg;
        let dcfg = &draft.cfg;
        let mut tpool = BlockPool::new(tcfg.n_layers, tcfg.d_model, bs, b * per_seq);
        let mut dpool = BlockPool::new(dcfg.n_layers, dcfg.d_model, bs, b * per_seq);
        for (bi, row) in rows.iter_mut().enumerate() {
            let mut rng = sampling.map(|p| seq_rng(p.seed, bi));
            let mut tc = PagedKvCache::new(&tpool);
            let mut dc = PagedKvCache::new(&dpool);
            // Prefill + first token, exactly like the plain paths.
            let logits = target.forward_chunk_paged(&row[..], &mut tc, &mut tpool)?;
            let tok = pick(logits.row(t0 - 1), sampling, rng.as_mut());
            row.push(tok);
            let mut emitted = 1usize;
            while emitted < max_new {
                let remaining = max_new - emitted;
                let k_eff = k.min(remaining - 1);
                // -- draft: catch up on tokens it hasn't seen, propose --
                let mut props: Vec<i32> = Vec::with_capacity(k_eff);
                if k_eff > 0 {
                    let d0 = Instant::now();
                    let suffix = &row[dc.len()..];
                    let dl = draft.forward_chunk_paged(suffix, &mut dc, &mut dpool)?;
                    props.push(argmax(dl.row(suffix.len() - 1)) as i32);
                    while props.len() < k_eff {
                        let last = [*props.last().expect("non-empty proposals")];
                        let mut refs = vec![&mut dc];
                        let dl = draft.forward_step_paged(&last, &mut refs, &mut dpool)?;
                        props.push(argmax(dl.row(0)) as i32);
                    }
                    draft_secs += d0.elapsed().as_secs_f64();
                }
                // -- target: one multi-position verify chunk --
                let mut chunk = vec![*row.last().expect("prompt is non-empty")];
                chunk.extend_from_slice(&props);
                let vl = target.forward_chunk_paged(&chunk, &mut tc, &mut tpool)?;
                let (toks, acc) =
                    accept_tokens(&vl, 0, &props, sampling, rng.as_mut(), remaining, None);
                proposed += props.len();
                accepted += acc;
                emitted += toks.len();
                row.extend_from_slice(&toks);
                // -- rollback: pop the rejected positions --
                tc.truncate(row.len() - 1, &mut tpool);
                dc.truncate(row.len() - 1, &mut dpool);
            }
            tc.release_all(&mut tpool);
            dc.release_all(&mut dpool);
        }
    }
    Ok(SpecGenReport {
        gen: GenReport {
            tokens: rows,
            prompt_len: t0,
            new_tokens: max_new,
            wall_secs: start.elapsed().as_secs_f64(),
        },
        proposed,
        accepted,
        draft_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logit_rows(rows: &[&[f32]]) -> Tensor {
        let v = rows[0].len();
        let mut t = Tensor::zeros(&[rows.len(), v]);
        for (i, r) in rows.iter().enumerate() {
            t.data_mut()[i * v..(i + 1) * v].copy_from_slice(r);
        }
        t
    }

    #[test]
    fn greedy_accept_walk() {
        // argmax rows: 2, 0, 1 — proposals [2, 0]: both accepted + bonus.
        let l = logit_rows(&[&[0.0, 1.0, 5.0], &[9.0, 1.0, 2.0], &[0.0, 7.0, 2.0]]);
        let (toks, acc) = accept_tokens(&l, 0, &[2, 0], None, None, 10, None);
        assert_eq!(toks, vec![2, 0, 1], "all accepted + bonus token");
        assert_eq!(acc, 2);

        // first proposal wrong: the target's argmax is the correction.
        let (toks, acc) = accept_tokens(&l, 0, &[1, 0], None, None, 10, None);
        assert_eq!(toks, vec![2], "mismatch emits the target pick and stops");
        assert_eq!(acc, 0);

        // second proposal wrong.
        let (toks, acc) = accept_tokens(&l, 0, &[2, 1], None, None, 10, None);
        assert_eq!(toks, vec![2, 0]);
        assert_eq!(acc, 1);
    }

    #[test]
    fn accept_respects_remaining_and_stop() {
        let l = logit_rows(&[&[0.0, 1.0, 5.0], &[9.0, 1.0, 2.0], &[0.0, 7.0, 2.0]]);
        let (toks, acc) = accept_tokens(&l, 0, &[2, 0], None, None, 1, None);
        assert_eq!(toks, vec![2], "remaining caps the cycle");
        assert_eq!(acc, 1);

        // an accepted token that is the stop token ends the cycle there
        let (toks, acc) = accept_tokens(&l, 0, &[2, 0], None, None, 10, Some(2));
        assert_eq!(toks, vec![2]);
        assert_eq!(acc, 1);
        // a corrected token that is the stop token also ends it
        let (toks, _) = accept_tokens(&l, 0, &[1, 0], None, None, 10, Some(2));
        assert_eq!(toks, vec![2]);
    }

    #[test]
    fn empty_proposals_is_a_plain_step() {
        let l = logit_rows(&[&[0.0, 1.0, 5.0]]);
        let (toks, acc) = accept_tokens(&l, 0, &[], None, None, 4, None);
        assert_eq!(toks, vec![2], "k = 0 emits exactly the target pick");
        assert_eq!(acc, 0);
    }

    #[test]
    fn rolling_window_collapse() {
        let pool = BlockPool::new(1, 2, 4, 4);
        let mut d = DraftState::new(&pool);
        for _ in 0..ACCEPT_WINDOW - 1 {
            d.note_cycle(4, 0);
            assert!(!d.collapsed(), "short history never collapses");
        }
        d.note_cycle(4, 0);
        assert!(d.collapsed(), "a full window of rejections collapses");
        // a healthy stretch pushes the bad cycles out of the window
        for _ in 0..ACCEPT_WINDOW {
            d.note_cycle(4, 4);
        }
        assert!(!d.collapsed());
    }
}
