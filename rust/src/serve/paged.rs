//! Per-sequence paged KV caches: a block table over [`BlockPool`] pages.
//!
//! A [`PagedKvCache`] is the paged replacement for the flat
//! [`crate::serve::kv::KvCache`] slab: instead of one worst-case
//! `prompt_len + max_new` buffer, the sequence holds an ordered table of
//! fixed-size block ids and grows **on demand** — one page at a time —
//! as decode appends positions.  The flat cache stays alive as the
//! bit-exact equivalence oracle (`tests/paged.rs` pins paged == flat for
//! block sizes 1/7/64), mirroring how `generate_recompute` anchors the
//! cached decode path.
//!
//! ## Prefix sharing + copy-on-write
//!
//! K/V rows depend only on the token prefix up to their position (causal
//! attention, absolute-position RoPE), so two requests whose prompts
//! share a prefix compute **bitwise identical** rows there.
//! [`PagedKvCache::fork_prefix`] exploits that: the child maps the
//! parent's physical blocks for the shared positions (refcount bump, no
//! copy).  Committed positions are immutable — rows are written once and
//! never rewritten — so full shared blocks never need copying.  Only a
//! *partially filled* shared tail block can see a write, and
//! [`PagedKvCache::reserve`] copies it to a private page first
//! (copy-on-write); both the forker and the forkee keep decoding
//! independently from that point.
//!
//! Writers must call `reserve` before `write_rows`: reserve is where the
//! block budget is enforced (admission backoff / capacity finish) and
//! where CoW happens, so the write path itself stays a straight scatter.

use crate::error::{Error, Result};
use crate::serve::block::{BlockPool, KvSegment};

/// One sequence's KV state: an ordered block table plus the committed
/// length.  All layers share the table (a block stores every layer's
/// rows for its positions) and the same `len`, exactly like the flat
/// cache: layers write the same positions during one forward pass and
/// the caller commits once with [`PagedKvCache::advance`].
pub struct PagedKvCache {
    n_layers: usize,
    d: usize,
    block_size: usize,
    len: usize,
    /// Physical block ids, ascending position order: `table[i]` holds
    /// positions `[i * block_size, (i + 1) * block_size)`.
    table: Vec<usize>,
}

impl PagedKvCache {
    /// An empty cache shaped for `pool`'s model.  The cache must only
    /// ever be used with the pool that shaped it.
    pub fn new(pool: &BlockPool) -> Self {
        PagedKvCache {
            n_layers: pool.n_layers(),
            d: pool.d(),
            block_size: pool.block_size(),
            len: 0,
            table: Vec::new(),
        }
    }

    /// Rebuild a cache from a restored block table (tier restore): the
    /// caller has already `try_alloc`'d every id in `table` and imported
    /// the spilled bytes into them, so this just reattaches the mapping
    /// and the committed length.  Shape comes from `pool` exactly like
    /// [`PagedKvCache::new`].
    pub fn from_parts(pool: &BlockPool, table: Vec<usize>, len: usize) -> Self {
        debug_assert!(len <= table.len() * pool.block_size());
        PagedKvCache {
            n_layers: pool.n_layers(),
            d: pool.d(),
            block_size: pool.block_size(),
            len,
            table,
        }
    }

    /// Committed positions (the attention span of the next decode step).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions writable without another `reserve`.
    pub fn capacity(&self) -> usize {
        self.table.len() * self.block_size
    }

    /// Physical blocks currently mapped by this sequence.
    pub fn n_blocks(&self) -> usize {
        self.table.len()
    }

    /// Block id covering position `pos` (tests / introspection).
    pub fn block_at(&self, pos: usize) -> usize {
        self.table[pos / self.block_size]
    }

    /// This sequence's block table (panic recovery: [`BlockPool::rebuild`]
    /// recounts pool refs from the survivors' tables).
    pub fn table(&self) -> &[usize] {
        &self.table
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Check this cache was shaped for `model`-shaped K/V rows.
    pub fn check_shape(&self, n_layers: usize, d: usize) -> Result<()> {
        if self.n_layers != n_layers || self.d != d {
            return Err(Error::shape(format!(
                "PagedKvCache shaped for {} layers x d {}, model wants {} x {}",
                self.n_layers, self.d, n_layers, d
            )));
        }
        Ok(())
    }

    /// Blocks needed to hold `positions`.
    fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.block_size)
    }

    /// Fork a child that maps the parent's physical blocks for positions
    /// `[0, positions)` — refcount bumps only, no data copied.  The
    /// shared positions must already be committed in the parent (or be
    /// block-aligned and committed-by-the-same-batched-pass; the
    /// scheduler guarantees one of the two).  A partially shared tail
    /// block is copied on the child's (or parent's) next append.
    pub fn fork_prefix(
        parent: &PagedKvCache,
        positions: usize,
        pool: &mut BlockPool,
    ) -> Result<PagedKvCache> {
        let nb = parent.blocks_for(positions);
        if nb > parent.table.len() {
            return Err(Error::shape(format!(
                "fork_prefix: {positions} positions want {nb} blocks, parent maps {}",
                parent.table.len()
            )));
        }
        let table = parent.table[..nb].to_vec();
        for &id in &table {
            pool.retain(id);
        }
        Ok(PagedKvCache {
            n_layers: parent.n_layers,
            d: parent.d,
            block_size: parent.block_size,
            len: positions,
            table,
        })
    }

    /// Make positions `[len, upto)` writable: copy-on-write any shared
    /// block the write range touches, then allocate missing tail blocks.
    /// Fails (leaving already-acquired blocks mapped — callers that must
    /// be atomic roll back with [`PagedKvCache::release_all`]) when the
    /// pool budget is exhausted.
    pub fn reserve(&mut self, upto: usize, pool: &mut BlockPool) -> Result<()> {
        if upto <= self.len {
            return Ok(());
        }
        let first = self.len / self.block_size;
        let last = (upto - 1) / self.block_size;
        for bi in first..=last {
            if bi < self.table.len() {
                let id = self.table[bi];
                if pool.ref_count(id) > 1 {
                    // Shared tail page about to be written: copy it to a
                    // private page; the other holders keep the original.
                    let nid = pool.try_alloc().ok_or_else(|| exhausted(pool))?;
                    pool.copy_block(id, nid);
                    pool.release(id);
                    self.table[bi] = nid;
                }
            } else {
                debug_assert_eq!(bi, self.table.len(), "table grows in order");
                let nid = pool.try_alloc().ok_or_else(|| exhausted(pool))?;
                self.table.push(nid);
            }
        }
        Ok(())
    }

    /// Write `t = krows.len() / d` new K/V rows of `layer` at positions
    /// `len..len + t`, scattering across blocks.  Does NOT advance `len`
    /// (all layers write the same positions during one pass).  The range
    /// must have been `reserve`d.
    pub fn write_rows(
        &mut self,
        pool: &mut BlockPool,
        layer: usize,
        krows: &[f32],
        vrows: &[f32],
    ) -> Result<()> {
        debug_assert_eq!(krows.len(), vrows.len());
        let t = krows.len() / self.d;
        if self.len + t > self.capacity() {
            return Err(Error::shape(format!(
                "PagedKvCache overflow: {} + {t} rows > reserved capacity {} (call reserve first)",
                self.len,
                self.capacity()
            )));
        }
        let bs = self.block_size;
        let mut pos = self.len;
        let mut off = 0usize;
        while off < krows.len() {
            let slot = pos % bs;
            let take = (bs - slot).min(self.len + t - pos);
            let id = self.table[pos / bs];
            let n = take * self.d;
            pool.write_rows(id, layer, slot, &krows[off..off + n], &vrows[off..off + n]);
            pos += take;
            off += take * self.d;
        }
        Ok(())
    }

    /// Commit `t` freshly written positions.
    pub fn advance(&mut self, t: usize) {
        debug_assert!(self.len + t <= self.capacity());
        self.len += t;
    }

    /// Seal every *fully committed* block of this sequence: quantize its
    /// planes and drop the f32 staging (no-op under the f32 layout and on
    /// already-sealed pages, so calling this every tick only pays for
    /// newly filled blocks).  Callers invoke it at quiescent points — the
    /// scheduler at end of tick (after speculative rollback), the ppl
    /// harness between chunks — so sealed rows are always accepted-final.
    /// The partially filled tail block stays staged (its f32 rows are the
    /// write buffer); a sealed page that later takes a write — a CoW fork
    /// extending an unaligned prefix, or a rollback below a block
    /// boundary — is reopened transparently by the pool.
    pub fn seal_committed(&self, pool: &mut BlockPool) {
        let full = (self.len / self.block_size).min(self.table.len());
        for &id in &self.table[..full] {
            pool.seal_block(id);
        }
    }

    /// Per-block segment views of `layer` covering positions `[0, upto)`,
    /// in ascending position order — the paged attention path iterates
    /// these so the accumulation order (and therefore every bit of the
    /// softmax) matches the flat layout.  Staged pages yield raw f32 row
    /// slices; sealed pages yield quantized views the attention core
    /// dequantizes during the walk.
    pub fn segments<'p>(
        &self,
        pool: &'p BlockPool,
        layer: usize,
        upto: usize,
    ) -> Vec<KvSegment<'p>> {
        let mut segs = Vec::with_capacity(upto.div_ceil(self.block_size));
        self.segments_into(pool, layer, upto, &mut segs);
        segs
    }

    /// [`PagedKvCache::segments`] into caller-owned scratch (cleared
    /// here), so the batched decode hot path reuses ONE vector across
    /// the sequences of a layer instead of allocating per sequence.
    pub fn segments_into<'p>(
        &self,
        pool: &'p BlockPool,
        layer: usize,
        upto: usize,
        out: &mut Vec<KvSegment<'p>>,
    ) {
        debug_assert!(upto <= self.capacity());
        out.clear();
        let bs = self.block_size;
        let mut pos = 0usize;
        while pos < upto {
            let take = bs.min(upto - pos);
            let id = self.table[pos / bs];
            out.push(pool.segment(id, layer, take));
            pos += take;
        }
    }

    /// Roll back to at most `len` committed positions, releasing tail
    /// blocks that no longer hold any committed position (speculative
    /// decode pops rejected drafted tokens this way).  Refcount-aware: a
    /// dropped block that a forked sequence still holds is NOT scrubbed —
    /// this sequence only drops its table entry and the other holders
    /// keep reading their committed (immutable) rows.  Positions beyond
    /// `len` inside the kept tail block become garbage; the next
    /// [`PagedKvCache::reserve`] + write pass overwrites them before any
    /// read, and `reserve` still copy-on-writes the tail if it is shared.
    /// A `len` at or past the current length is a no-op.
    pub fn truncate(&mut self, len: usize, pool: &mut BlockPool) {
        if len >= self.len {
            return;
        }
        let keep = self.blocks_for(len);
        for id in self.table.drain(keep..) {
            pool.release(id);
        }
        self.len = len;
    }

    /// Drop reserved-but-uncommitted tail blocks.  A failed multi-block
    /// [`PagedKvCache::reserve`] leaves the blocks it did acquire mapped
    /// (so a successful retry is cheap); callers that will NOT retry at
    /// that size call this so the spare pages go back to the budget
    /// instead of starving other sequences.  Committed positions are
    /// untouched.
    pub fn trim_reserve(&mut self, pool: &mut BlockPool) {
        let keep = self.blocks_for(self.len);
        for id in self.table.drain(keep..) {
            pool.release(id);
        }
    }

    /// Release every mapped block back to the pool (eviction / rollback).
    pub fn release_all(&mut self, pool: &mut BlockPool) {
        for id in self.table.drain(..) {
            pool.release(id);
        }
        self.len = 0;
    }
}

fn exhausted(pool: &BlockPool) -> Error {
    Error::config(format!(
        "KV block pool exhausted ({} blocks of {} positions)",
        pool.max_blocks(),
        pool.block_size()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(d: usize, t: usize, base: f32) -> Vec<f32> {
        (0..t * d).map(|i| base + i as f32).collect()
    }

    #[test]
    fn write_scatters_across_blocks_and_segments_read_back() {
        let (layers, d, bs) = (2usize, 3usize, 4usize);
        let mut pool = BlockPool::new(layers, d, bs, 8);
        let mut c = PagedKvCache::new(&pool);
        assert!(c.is_empty());

        // 6 positions straddle two 4-position blocks
        c.reserve(6, &mut pool).unwrap();
        assert_eq!(c.n_blocks(), 2);
        let k = rows(d, 6, 0.0);
        let v = rows(d, 6, 100.0);
        c.write_rows(&mut pool, 0, &k, &v).unwrap();
        c.write_rows(&mut pool, 1, &v, &k).unwrap();
        c.advance(6);
        assert_eq!(c.len(), 6);

        let segs = c.segments(&pool, 0, 6);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0].as_f32().0, &k[..4 * d]);
        assert_eq!(segs[1].as_f32().0, &k[4 * d..]);
        assert_eq!(segs[0].as_f32().1, &v[..4 * d]);
        let segs = c.segments(&pool, 1, 5);
        assert_eq!(segs[1].as_f32().0, &v[4 * d..5 * d], "upto truncates the tail segment");

        // appending one more position lands in block 1 slot 2
        c.reserve(7, &mut pool).unwrap();
        let k2 = rows(d, 1, 50.0);
        c.write_rows(&mut pool, 0, &k2, &k2).unwrap();
        c.advance(1);
        let segs = c.segments(&pool, 0, 7);
        assert_eq!(&segs[1].as_f32().0[2 * d..], &k2[..]);

        // writing past reserved capacity is an error, not a panic
        assert!(c.write_rows(&mut pool, 0, &rows(d, 2, 0.0), &rows(d, 2, 0.0)).is_err());
    }

    #[test]
    fn fork_shares_blocks_and_cow_splits_the_tail() {
        let (layers, d, bs) = (1usize, 2usize, 4usize);
        let mut pool = BlockPool::new(layers, d, bs, 8);
        let mut a = PagedKvCache::new(&pool);
        a.reserve(6, &mut pool).unwrap();
        let k = rows(d, 6, 0.0);
        a.write_rows(&mut pool, 0, &k, &k).unwrap();
        a.advance(6);
        assert_eq!(pool.stats().used_blocks, 2);

        // child shares 5 positions: full block 0 + partial tail block 1
        let mut b = PagedKvCache::fork_prefix(&a, 5, &mut pool).unwrap();
        assert_eq!(b.len(), 5);
        assert_eq!(b.n_blocks(), 2);
        assert_eq!(b.block_at(0), a.block_at(0));
        assert_eq!(b.block_at(4), a.block_at(4));
        assert_eq!(pool.ref_count(a.block_at(0)), 2);
        assert_eq!(pool.stats().used_blocks, 2, "sharing allocates nothing");
        assert_eq!(pool.stats().shared_blocks, 2);

        // child's shared view reads the parent's rows
        let segs = b.segments(&pool, 0, 5);
        assert_eq!(segs[1].as_f32().0, &k[4 * d..5 * d]);

        // child appends at position 5 -> shared tail block is copied
        let shared_tail = a.block_at(4);
        b.reserve(6, &mut pool).unwrap();
        assert_ne!(b.block_at(4), shared_tail, "CoW gave the child a private tail");
        assert_eq!(a.block_at(4), shared_tail, "parent keeps the original");
        assert_eq!(pool.ref_count(shared_tail), 1);
        assert_eq!(pool.stats().shared_blocks, 1, "only block 0 still shared");
        let kb = rows(d, 1, 500.0);
        b.write_rows(&mut pool, 0, &kb, &kb).unwrap();
        b.advance(1);
        // the copied tail still carries the shared prefix row at slot 0
        let segs = b.segments(&pool, 0, 6);
        assert_eq!(&segs[1].as_f32().0[..d], &k[4 * d..5 * d]);
        assert_eq!(&segs[1].as_f32().0[d..2 * d], &kb[..]);
        // and the parent's tail is untouched by the child's write
        let segs = a.segments(&pool, 0, 6);
        assert_eq!(segs[1].as_f32().0, &k[4 * d..]);

        // full release returns every page
        b.release_all(&mut pool);
        a.release_all(&mut pool);
        let s = pool.stats();
        assert_eq!(s.used_blocks, 0);
        assert_eq!(s.shared_blocks, 0);
        assert!(s.peak_shared_blocks >= 2, "peak sharing survives the run");
    }

    #[test]
    fn parent_append_into_shared_tail_also_cows() {
        let (layers, d, bs) = (1usize, 2usize, 4usize);
        let mut pool = BlockPool::new(layers, d, bs, 8);
        let mut a = PagedKvCache::new(&pool);
        a.reserve(5, &mut pool).unwrap();
        let k = rows(d, 5, 0.0);
        a.write_rows(&mut pool, 0, &k, &k).unwrap();
        a.advance(5);

        let b = PagedKvCache::fork_prefix(&a, 5, &mut pool).unwrap();
        let tail = a.block_at(4);
        assert_eq!(pool.ref_count(tail), 2);

        // now the PARENT appends: it must CoW, the child keeps `tail`
        a.reserve(6, &mut pool).unwrap();
        assert_ne!(a.block_at(4), tail);
        assert_eq!(b.block_at(4), tail);
        assert_eq!(pool.ref_count(tail), 1);
    }

    #[test]
    fn trim_reserve_returns_spare_tail_blocks() {
        // budget 4: a commits 3 positions (1 block), b holds 2 blocks
        let mut pool = BlockPool::new(1, 2, 4, 4);
        let mut a = PagedKvCache::new(&pool);
        a.reserve(3, &mut pool).unwrap();
        let k = rows(2, 3, 0.0);
        a.write_rows(&mut pool, 0, &k, &k).unwrap();
        a.advance(3);
        let mut b = PagedKvCache::new(&pool);
        b.reserve(8, &mut pool).unwrap();

        // a's 3-block ask acquires the last free page, then fails — the
        // spare page stays mapped until trim_reserve hands it back
        assert!(a.reserve(12, &mut pool).is_err());
        assert_eq!(a.n_blocks(), 2);
        assert_eq!(pool.available(), 0);
        a.trim_reserve(&mut pool);
        assert_eq!(a.n_blocks(), 1);
        assert_eq!(a.len(), 3, "committed positions untouched");
        assert_eq!(pool.available(), 1, "the spare page is reclaimable again");
        let segs = a.segments(&pool, 0, 3);
        assert_eq!(segs[0].as_f32().0, &k[..]);

        a.release_all(&mut pool);
        b.release_all(&mut pool);
    }

    #[test]
    fn reserve_fails_when_budget_exhausted() {
        let mut pool = BlockPool::new(1, 2, 4, 2);
        let mut a = PagedKvCache::new(&pool);
        a.reserve(8, &mut pool).unwrap(); // both blocks
        let mut b = PagedKvCache::new(&pool);
        assert!(b.reserve(1, &mut pool).is_err(), "no blocks left");
        a.release_all(&mut pool);
        assert!(b.reserve(1, &mut pool).is_ok(), "reclaimed after release");
        b.release_all(&mut pool);
    }

    #[test]
    fn seal_committed_quantizes_full_blocks_cow_and_truncate_survive() {
        use crate::kernels::dequant::kv_dequant_scalar;
        use crate::serve::block::KvLayout;
        let (layers, d, bs) = (1usize, 8usize, 4usize);
        let mut pool =
            BlockPool::with_layout(layers, d, bs, 8, KvLayout::Quant { bits: 8, group: 8 });
        let mut a = PagedKvCache::new(&pool);
        a.reserve(6, &mut pool).unwrap();
        let k = rows(d, 6, 0.0);
        a.write_rows(&mut pool, 0, &k, &k).unwrap();
        a.advance(6);
        a.seal_committed(&mut pool);
        assert!(pool.is_sealed(a.block_at(0)), "full block sealed");
        assert!(!pool.is_sealed(a.block_at(4)), "partial tail stays staged");

        let segs = a.segments(&pool, 0, 6);
        match &segs[0] {
            KvSegment::Quant { rows, .. } => assert_eq!(*rows, 4),
            KvSegment::F32(..) => panic!("sealed block must read quantized"),
        }
        assert_eq!(segs[1].as_f32().0, &k[4 * d..6 * d], "tail still reads f32");

        // Unaligned fork into the sealed page: the child's append CoWs
        // the page, and the child's write reopens only the private copy.
        let mut b = PagedKvCache::fork_prefix(&a, 2, &mut pool).unwrap();
        b.reserve(3, &mut pool).unwrap();
        let kb = rows(d, 1, 500.0);
        b.write_rows(&mut pool, 0, &kb, &kb).unwrap();
        b.advance(1);
        assert_ne!(b.block_at(0), a.block_at(0), "CoW split the sealed page");
        assert!(pool.is_sealed(a.block_at(0)), "parent's page stays sealed");
        assert!(!pool.is_sealed(b.block_at(0)), "child's copy reopened for the write");

        // The child's inherited rows are bitwise what the parent's sealed
        // reads return for those positions.
        let mut parent_rows = vec![0.0f32; 2 * d];
        match pool.segment(a.block_at(0), 0, 2) {
            KvSegment::Quant { k, .. } => kv_dequant_scalar(&k, 0, &mut parent_rows),
            KvSegment::F32(..) => panic!("parent page should be sealed"),
        }
        let cb = b.segments(&pool, 0, 3);
        assert_eq!(&cb[0].as_f32().0[..2 * d], &parent_rows[..]);

        // Rollback below a sealed block boundary: the next reserve+write
        // reopens the page and overwrites the popped slots.
        a.truncate(3, &mut pool);
        assert_eq!(a.n_blocks(), 1);
        a.reserve(4, &mut pool).unwrap();
        let k3 = rows(d, 1, 900.0);
        a.write_rows(&mut pool, 0, &k3, &k3).unwrap();
        a.advance(1);
        assert!(!pool.is_sealed(a.block_at(0)), "write into sealed page reopened it");
        let segs = a.segments(&pool, 0, 4);
        assert_eq!(&segs[0].as_f32().0[3 * d..], &k3[..]);

        b.release_all(&mut pool);
        a.release_all(&mut pool);
    }
}
