//! Continuous-batching scheduler: step-granular admission and eviction.
//!
//! The scheduler owns the set of in-flight sequences.  Every call to
//! [`Scheduler::step`] (1) admits pending requests into the running batch
//! while there is room — each admission prefills the prompt into a pooled
//! [`KvCache`] and emits the request's first token immediately, so a
//! request that arrives mid-flight starts decoding before earlier
//! requests finish; (2) runs ONE incremental decode step for the whole
//! batch through `PackedModel::forward_step`; (3) evicts finished
//! sequences, returning their caches to the pool.  Per-request stats
//! (queue wait, prefill time, decode time, worst inter-token gap) ride on
//! the final [`StepEvent::Done`].
//!
//! All attention state is per-sequence, and every batched operation in
//! the decode path is row-independent, so batch composition never changes
//! a request's token stream — the invariance `tests/serve.rs` checks.

use std::collections::VecDeque;
use std::time::Instant;

use crate::error::Result;
use crate::infer::PackedModel;
use crate::serve::decode::pick;
use crate::serve::kv::{KvCache, KvPool};
use crate::serve::sampling::{seq_rng, SamplingParams};
use crate::tensor::Rng;

/// Scheduler limits.
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Maximum sequences decoding concurrently.
    pub max_batch: usize,
    /// Hard cap on a request's `max_new` (larger asks are clamped).
    pub max_new_cap: usize,
    /// Maximum admissible prompt length (longer requests are rejected).
    pub max_prompt: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig { max_batch: 8, max_new_cap: 512, max_prompt: 1024 }
    }
}

/// One generation request as the scheduler sees it.
pub struct GenRequest {
    /// Engine-unique key (routing); the client-chosen `id` is echoed in
    /// every event.
    pub key: u64,
    pub id: String,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// `None` = greedy argmax.
    pub sampling: Option<SamplingParams>,
    /// Optional stop token: generation ends when it is emitted.
    pub stop: Option<i32>,
    pub queued_at: Instant,
}

/// Why a sequence left the batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Emitted `max_new` tokens.
    Length,
    /// Emitted the request's stop token.
    Stop,
    /// KV cache exhausted (belt-and-braces; admission sizes caches so
    /// this should not trigger).
    Capacity,
    /// Dropped by `Scheduler::cancel` (e.g. client went away).
    Cancelled,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::Capacity => "capacity",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// Wall-clock accounting for one completed request.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestStats {
    /// Submission -> admission.
    pub queue_secs: f64,
    /// Prompt prefill (includes the first sampled token).
    pub prefill_secs: f64,
    /// Admission -> completion.
    pub total_secs: f64,
    /// Worst gap between consecutive emitted tokens.
    pub max_inter_token_secs: f64,
    /// Generated (non-prompt) tokens.
    pub n_new_tokens: usize,
}

impl RequestStats {
    /// Generated tokens per second of post-admission wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.total_secs <= 0.0 {
            return 0.0;
        }
        self.n_new_tokens as f64 / self.total_secs
    }
}

/// What a scheduler step produced, in emission order.
pub enum StepEvent {
    /// One streamed token (index counts generated tokens from 0).
    Token { key: u64, id: String, index: usize, token: i32 },
    /// Request finished; `tokens` holds prompt + generated.
    Done {
        key: u64,
        id: String,
        tokens: Vec<i32>,
        prompt_len: usize,
        finish: FinishReason,
        stats: RequestStats,
    },
    /// Request failed validation and never entered the batch.
    Rejected { key: u64, id: String, reason: String },
}

struct Running {
    req: GenRequest,
    cache: KvCache,
    rng: Option<Rng>,
    /// prompt + generated tokens.
    tokens: Vec<i32>,
    emitted: usize,
    admitted_at: Instant,
    prefill_secs: f64,
    last_token_at: Instant,
    max_gap: f64,
    finish: Option<FinishReason>,
}

impl Running {
    fn note_token(&mut self, now: Instant) {
        let gap = now.duration_since(self.last_token_at).as_secs_f64();
        if self.emitted > 1 && gap > self.max_gap {
            self.max_gap = gap;
        }
        self.last_token_at = now;
    }

    fn check_finished(&mut self, tok: i32) {
        if self.req.stop == Some(tok) {
            self.finish = Some(FinishReason::Stop);
        } else if self.emitted >= self.req.max_new {
            self.finish = Some(FinishReason::Length);
        } else if self.cache.remaining() == 0 {
            self.finish = Some(FinishReason::Capacity);
        }
    }
}

/// The continuous-batching scheduler.
pub struct Scheduler<'m> {
    model: &'m PackedModel,
    cfg: SchedConfig,
    pending: VecDeque<GenRequest>,
    active: Vec<Running>,
    pool: KvPool,
    completed: usize,
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m PackedModel, cfg: SchedConfig) -> Self {
        let pool = KvPool::new(model.cfg.n_layers, model.cfg.d_model);
        Scheduler { model, cfg, pending: VecDeque::new(), active: Vec::new(), pool, completed: 0 }
    }

    /// Queue a request for admission at the next step.
    pub fn submit(&mut self, req: GenRequest) {
        self.pending.push_back(req);
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty()
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    pub fn n_completed(&self) -> usize {
        self.completed
    }

    /// Drop a request wherever it is (pending or mid-decode).  Active
    /// sequences are evicted at the next step with `Cancelled`.
    pub fn cancel(&mut self, key: u64) {
        self.pending.retain(|r| r.key != key);
        for r in self.active.iter_mut() {
            if r.req.key == key && r.finish.is_none() {
                r.finish = Some(FinishReason::Cancelled);
            }
        }
    }

    /// Drop everything (engine shutdown).
    pub fn clear(&mut self) {
        self.pending.clear();
        self.active.clear();
    }

    /// Admit pending requests while the batch has room.  Each admission
    /// prefills and emits the first token.
    fn admit(&mut self, events: &mut Vec<StepEvent>) -> Result<()> {
        while self.active.len() < self.cfg.max_batch {
            let Some(mut req) = self.pending.pop_front() else { break };
            if req.prompt.is_empty() {
                events.push(StepEvent::Rejected {
                    key: req.key,
                    id: req.id,
                    reason: "empty prompt".to_string(),
                });
                continue;
            }
            if req.prompt.len() > self.cfg.max_prompt {
                events.push(StepEvent::Rejected {
                    key: req.key,
                    id: req.id,
                    reason: format!(
                        "prompt length {} > max {}",
                        req.prompt.len(),
                        self.cfg.max_prompt
                    ),
                });
                continue;
            }
            req.max_new = req.max_new.clamp(1, self.cfg.max_new_cap);

            let admitted_at = Instant::now();
            let mut cache = self.pool.take(req.prompt.len() + req.max_new);
            let logits = self.model.forward_chunk(&req.prompt, &mut cache)?;
            let mut rng = req.sampling.map(|p| seq_rng(p.seed, 0));
            let tok = pick(
                logits.row(req.prompt.len() - 1),
                req.sampling.as_ref(),
                rng.as_mut(),
            );
            let now = Instant::now();
            let mut run = Running {
                tokens: {
                    let mut t = req.prompt.clone();
                    t.push(tok);
                    t
                },
                cache,
                rng,
                emitted: 1,
                admitted_at,
                prefill_secs: now.duration_since(admitted_at).as_secs_f64(),
                last_token_at: now,
                max_gap: 0.0,
                finish: None,
                req,
            };
            events.push(StepEvent::Token {
                key: run.req.key,
                id: run.req.id.clone(),
                index: 0,
                token: tok,
            });
            run.check_finished(tok);
            self.active.push(run);
        }
        Ok(())
    }

    /// One scheduler step: admit, decode one token for every live
    /// sequence, evict finished ones.  Returns events in emission order.
    pub fn step(&mut self) -> Result<Vec<StepEvent>> {
        let mut events = Vec::new();
        self.admit(&mut events)?;

        // -- one batched decode step over sequences still running --
        let mut idxs: Vec<usize> = Vec::new();
        let mut toks: Vec<i32> = Vec::new();
        let mut picked: Vec<(usize, i32)> = Vec::new();
        {
            let mut caches: Vec<&mut KvCache> = Vec::new();
            let mut rngs: Vec<&mut Option<Rng>> = Vec::new();
            let mut samplings: Vec<Option<SamplingParams>> = Vec::new();
            for (i, r) in self.active.iter_mut().enumerate() {
                if r.finish.is_none() {
                    idxs.push(i);
                    toks.push(*r.tokens.last().expect("active sequence has tokens"));
                    samplings.push(r.req.sampling);
                    let Running { cache, rng, .. } = r;
                    caches.push(cache);
                    rngs.push(rng);
                }
            }
            if !idxs.is_empty() {
                let logits = self.model.forward_step(&toks, &mut caches)?;
                for (j, &i) in idxs.iter().enumerate() {
                    let tok = pick(logits.row(j), samplings[j].as_ref(), rngs[j].as_mut());
                    picked.push((i, tok));
                }
            }
        }
        let now = Instant::now();
        for (i, tok) in picked {
            let r = &mut self.active[i];
            r.tokens.push(tok);
            r.emitted += 1;
            r.note_token(now);
            events.push(StepEvent::Token {
                key: r.req.key,
                id: r.req.id.clone(),
                index: r.emitted - 1,
                token: tok,
            });
            r.check_finished(tok);
        }

        // -- evict finished sequences (stable order) --
        let mut kept = Vec::with_capacity(self.active.len());
        for r in self.active.drain(..) {
            match r.finish {
                None => kept.push(r),
                Some(finish) => {
                    let done_at = Instant::now();
                    let stats = RequestStats {
                        queue_secs: r.admitted_at.duration_since(r.req.queued_at).as_secs_f64(),
                        prefill_secs: r.prefill_secs,
                        total_secs: done_at.duration_since(r.admitted_at).as_secs_f64(),
                        max_inter_token_secs: r.max_gap,
                        n_new_tokens: r.emitted,
                    };
                    self.completed += 1;
                    self.pool.give(r.cache);
                    events.push(StepEvent::Done {
                        key: r.req.key,
                        id: r.req.id,
                        tokens: r.tokens,
                        prompt_len: r.req.prompt.len(),
                        finish,
                        stats,
                    });
                }
            }
        }
        self.active = kept;
        Ok(events)
    }
}
