//! Continuous-batching scheduler over paged KV memory.
//!
//! The scheduler owns the set of in-flight sequences AND the model-wide
//! [`BlockPool`] their K/V pages come from.  Every call to
//! [`Scheduler::step`]:
//!
//! 1. **Admits** pending requests while the batch has room *and the
//!    block budget covers each prompt* — a request whose prompt cannot
//!    get its pages backs off at the front of the queue until eviction
//!    frees blocks (no worst-case `prompt + max_new` reservation; decode
//!    pages are allocated on demand).  Each admission first maps the
//!    longest shareable prompt prefix of any live (or same-tick) request
//!    onto the same physical blocks (refcount bump, no copy, no
//!    recompute), then ALL admissions of the tick prefill their
//!    remaining suffixes in ONE batched [`PackedModel::prefill_batch`]
//!    pass and emit their first tokens.
//! 2. Runs ONE incremental decode step for the whole batch through
//!    [`PackedModel::forward_step_paged`], growing block tables by at
//!    most one page per sequence; a sequence the budget cannot extend
//!    finishes with `capacity` instead of poisoning the batch.
//! 3. **Evicts** finished sequences, releasing their refcounted blocks
//!    back to the pool (shared pages survive until the last holder
//!    leaves).
//!
//! With a draft model attached ([`Scheduler::with_draft`] +
//! `SchedConfig::speculate`), step 2 becomes a speculative draft/verify
//! cycle for eligible sequences: the draft proposes `k` greedy tokens
//! (batched catch-up prefill + single-token steps on its own KV pool),
//! the target verifies every sequence's chunk in ONE
//! [`PackedModel::forward_verify_paged`] pass, and rejected positions
//! are popped with [`PagedKvCache::truncate`].  Sequences fall back to
//! the plain step — per sequence, permanently — when the draft pool is
//! exhausted or their rolling acceptance collapses.
//!
//! With a disk tier attached ([`Scheduler::attach_tier`], `--kv-spill`),
//! block exhaustion stops being terminal: admission preempts the
//! coldest active sequence to the spill file instead of backing off,
//! a decode reserve miss suspends the missing sequence instead of
//! finishing it with `capacity`, suspended sequences resume FIFO as
//! pages free up, `session`-tagged requests park their final KV at
//! finish (or disconnect) and continue later without re-prefilling the
//! stored history, and fully committed prompt pages are published to a
//! content-keyed persistent prefix store any later request can fork
//! from disk.  Pages move verbatim (CRC-checked), so a suspended or
//! session-resumed stream is bitwise what a memory-only run emits.
//!
//! All attention state is per-sequence, every batched operation in the
//! decode path is row-independent, and shared prefix pages hold rows
//! that are bitwise what the sharer would have computed itself — so
//! batch composition, paging, prefix sharing, and speculation never
//! change a request's token stream (`tests/serve.rs` + `tests/paged.rs`
//! + `tests/spec.rs`).

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::error::Result;
use crate::infer::{argmax, AdapterSet, PackedModel};
use crate::obs::trace::{
    KernelTickDelta, PH_ADMIT, PH_DECODE, PH_DRAFT, PH_EMIT, PH_PREFILL, PH_SAMPLE, PH_TIER,
    PH_VERIFY,
};
use crate::obs::{profile, RequestSpan, Telemetry, TickRecord};
use crate::serve::adapters::AdapterRegistry;
use crate::serve::block::{BlockPool, KvLayout, KvStats};
use crate::serve::decode::pick;
use crate::serve::paged::PagedKvCache;
use crate::serve::sampling::{seq_rng, SamplingParams};
use crate::serve::spec::{accept_tokens, DraftState, SpecEngine, SpecStats};
use crate::serve::tier::{SessionEntry, TierStats, TieredKv};
use crate::tensor::Rng;

/// Scheduler limits.
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Maximum sequences decoding concurrently.
    pub max_batch: usize,
    /// Hard cap on a request's `max_new` (larger asks are rejected with
    /// a `bad_request` error frame — an explicit contract instead of a
    /// silent clamp).
    pub max_new_cap: usize,
    /// Maximum admissible prompt length (longer requests are rejected).
    pub max_prompt: usize,
    /// Positions per KV page (`--kv-block`).
    pub kv_block: usize,
    /// KV page budget (`--kv-blocks-total`); 0 = auto-size to
    /// `max_batch` worst-case sequences (paging then saves memory via
    /// sharing + on-demand growth rather than by refusing admissions).
    pub kv_blocks_total: usize,
    /// Draft tokens proposed per speculative cycle (`--speculate`);
    /// 0 = speculation off (a draft model, if any, is ignored).
    pub speculate: usize,
    /// Draft-side KV page budget (`--draft-kv-blocks-total`); 0 =
    /// auto-size like the target budget, plus the in-flight proposals.
    pub draft_kv_blocks_total: usize,
    /// Admission-queue bound (`--max-pending`); submissions past it are
    /// refused with an `overloaded` error frame.  0 = unbounded.
    pub max_pending: usize,
    /// Default per-request deadline in ms (`--deadline-ms`), applied to
    /// requests that omit `deadline_ms`.  0 = no default deadline.
    pub deadline_ms: u64,
    /// KV page storage width (`--kv-bits`): 16 = f32 pages (the bitwise
    /// oracle), 8/4 = group-wise affine-quantized sealed pages with one
    /// scale/zero per head slice.  Only the target pool quantizes; the
    /// speculative draft pool always stays f32 (it is tiny and its rows
    /// are popped every cycle, so sealing would never pay off).
    pub kv_bits: u32,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            max_batch: 8,
            max_new_cap: 512,
            max_prompt: 1024,
            kv_block: 32,
            kv_blocks_total: 0,
            speculate: 0,
            draft_kv_blocks_total: 0,
            max_pending: 1024,
            deadline_ms: 0,
            kv_bits: 16,
        }
    }
}

impl SchedConfig {
    /// Resolved block budget (auto-sizing applied).
    pub fn blocks_total(&self) -> usize {
        if self.kv_blocks_total > 0 {
            return self.kv_blocks_total;
        }
        let bs = self.kv_block.max(1);
        self.max_batch.max(1) * (self.max_prompt + self.max_new_cap).div_ceil(bs)
    }

    /// Resolved target-pool page layout: `kv_bits` 16 (or 0) keeps the
    /// f32 oracle; 8/4 quantize sealed pages per head slice (`group =
    /// head_dim`, so each head's K/V run carries its own affine grid).
    pub fn kv_layout(&self, head_dim: usize) -> KvLayout {
        match self.kv_bits {
            0 | 16 => KvLayout::F32,
            bits => KvLayout::Quant { bits, group: head_dim },
        }
    }

    /// Resolved draft-side block budget.
    pub fn draft_blocks_total(&self) -> usize {
        if self.draft_kv_blocks_total > 0 {
            return self.draft_kv_blocks_total;
        }
        let bs = self.kv_block.max(1);
        self.max_batch.max(1) * (self.max_prompt + self.max_new_cap + self.speculate).div_ceil(bs)
    }
}

/// One generation request as the scheduler sees it.
pub struct GenRequest {
    /// Engine-unique key (routing); the client-chosen `id` is echoed in
    /// every event.
    pub key: u64,
    pub id: String,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// `None` = greedy argmax.
    pub sampling: Option<SamplingParams>,
    /// Optional stop token: generation ends when it is emitted.
    pub stop: Option<i32>,
    /// Route through a registry adapter by name (`None` = the model's
    /// default path — its baked-in adapters if any, else the frozen
    /// base).  Unknown names are rejected at admission.
    pub adapter: Option<String>,
    pub queued_at: Instant,
    /// Absolute wall-clock budget: a request not admitted by then is
    /// rejected; a running sequence past it finishes with `deadline`.
    /// `None` = no deadline.
    pub deadline: Option<Instant>,
    /// Session id: when a disk tier is attached, this sequence's final
    /// KV parks in the spill file at finish (or disconnect), and a later
    /// request with the same id whose prompt extends the stored history
    /// resumes decoding without re-prefilling the shared positions.
    /// Ignored without a tier.
    pub session: Option<String>,
}

/// Why a sequence left the batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Emitted `max_new` tokens.
    Length,
    /// Emitted the request's stop token.
    Stop,
    /// KV block budget exhausted mid-decode (the sequence keeps what it
    /// streamed; its pages are reclaimed for waiting requests).
    Capacity,
    /// Dropped by `Scheduler::cancel` (e.g. client went away).
    Cancelled,
    /// The request's `deadline_ms` budget expired mid-decode (the
    /// sequence keeps what it streamed; its pages are reclaimed).
    Deadline,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::Capacity => "capacity",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Deadline => "deadline",
        }
    }
}

/// Wall-clock accounting for one completed request.
#[derive(Clone, Copy, Debug, Default)]
pub struct RequestStats {
    /// Submission -> admission.
    pub queue_secs: f64,
    /// Prompt prefill (the batched pass this request was prefilled in,
    /// including its first sampled token).
    pub prefill_secs: f64,
    /// Admission -> completion.
    pub total_secs: f64,
    /// Worst gap between consecutive emitted tokens.
    pub max_inter_token_secs: f64,
    /// Generated (non-prompt) tokens.
    pub n_new_tokens: usize,
    /// Prompt positions mapped from another request's pages instead of
    /// being recomputed (prefix sharing).
    pub shared_prefix_tokens: usize,
    /// Draft tokens proposed for this request (speculative decoding).
    pub spec_proposed: usize,
    /// Proposals the target accepted for this request.
    pub spec_accepted: usize,
}

impl RequestStats {
    /// Generated tokens per second of post-admission wall time.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.total_secs <= 0.0 {
            return 0.0;
        }
        self.n_new_tokens as f64 / self.total_secs
    }
}

/// What a scheduler step produced, in emission order.
pub enum StepEvent {
    /// One streamed token (index counts generated tokens from 0).
    Token { key: u64, id: String, index: usize, token: i32 },
    /// Request finished; `tokens` holds prompt + generated.
    Done {
        key: u64,
        id: String,
        tokens: Vec<i32>,
        prompt_len: usize,
        finish: FinishReason,
        stats: RequestStats,
    },
    /// Request failed validation and never entered the batch (or was
    /// quarantined after an engine panic).  `code` is the error-frame
    /// taxonomy value (`bad_request`, `deadline`, `internal`, ...).
    Rejected { key: u64, id: String, code: &'static str, reason: String },
}

struct Running {
    req: GenRequest,
    /// Resolved EXPLICIT adapter (`req.adapter` looked up at admission);
    /// `None` = the model's default path.  The `Arc` identity doubles as
    /// the grouping key for batched delta GEMMs and the donor-match key
    /// for prefix sharing.
    adapter: Option<Arc<AdapterSet>>,
    cache: PagedKvCache,
    rng: Option<Rng>,
    /// prompt + generated tokens.
    tokens: Vec<i32>,
    /// Wall-clock lifecycle (queue wait, prefill, inter-token gaps, spec
    /// tallies) — the single source [`RequestStats`] is derived from.
    span: RequestSpan,
    finish: Option<FinishReason>,
    /// Draft-side state when the engine speculates; `None` otherwise.
    draft: Option<DraftState>,
    /// Marked by a decode reserve miss when a tier is attached: the
    /// post-eviction sweep spills this sequence instead of finishing it.
    suspend: bool,
    /// Leading prompt pages already published to the prefix store (the
    /// publish walk skips sequences with nothing new to offer).
    prefix_published: usize,
}

impl Running {
    fn check_finished(&mut self, tok: i32) {
        if self.req.stop == Some(tok) {
            self.finish = Some(FinishReason::Stop);
        } else if self.span.emitted >= self.req.max_new {
            self.finish = Some(FinishReason::Length);
        }
    }

    /// Emit one generated token: record it, stamp timing, stream the
    /// event, and update the finish state.  Shared by the plain step
    /// and the speculative cycle so their bookkeeping cannot diverge.
    fn emit_token(&mut self, tok: i32, now: Instant, events: &mut Vec<StepEvent>) {
        self.tokens.push(tok);
        self.span.note_token(now);
        events.push(StepEvent::Token {
            key: self.req.key,
            id: self.req.id.clone(),
            index: self.span.emitted - 1,
            token: tok,
        });
        self.check_finished(tok);
    }
}

/// An admission staged for this tick's batched prefill.
struct Staged {
    req: GenRequest,
    adapter: Option<Arc<AdapterSet>>,
    cache: PagedKvCache,
    admitted_at: Instant,
    /// Prompt positions mapped from a donor's pages.
    shared: usize,
}

/// A sequence parked on the disk tier: everything [`Running`] owns
/// except the KV cache, whose pages live in spill slots instead of the
/// pool.  The adapter `Arc` (and its registry refcount) ride along so
/// the route cannot unload out from under a parked sequence; the
/// sampler stream resumes exactly where it stopped.
struct Suspended {
    req: GenRequest,
    adapter: Option<Arc<AdapterSet>>,
    rng: Option<Rng>,
    /// prompt + generated tokens so far.
    tokens: Vec<i32>,
    span: RequestSpan,
    /// Spill slots holding the block table, ascending page order.
    slots: Vec<u64>,
    /// Committed KV positions the slots hold.
    kv_len: usize,
    /// Whether speculation had permanently fallen back pre-suspend.
    draft_disabled: bool,
}

/// Adapter identity match for KV prefix sharing: adapters alter wk/wv,
/// so cached K/V rows depend on the adapter that wrote them — sharing
/// across different adapters (or adapter vs default) would splice another
/// task's K/V into this sequence's attention.
fn same_adapter(a: Option<&Arc<AdapterSet>>, b: Option<&Arc<AdapterSet>>) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(x), Some(y)) => Arc::ptr_eq(x, y),
        _ => false,
    }
}

/// Longest common prefix of two token slices.
fn common_prefix(a: &[i32], b: &[i32]) -> usize {
    a.iter().zip(b.iter()).take_while(|(x, y)| x == y).count()
}

/// The continuous-batching scheduler.
pub struct Scheduler<'m> {
    model: &'m PackedModel,
    cfg: SchedConfig,
    pending: VecDeque<GenRequest>,
    active: Vec<Running>,
    pool: BlockPool,
    completed: usize,
    /// Draft model + draft KV pool + counters when speculating.
    spec: Option<SpecEngine>,
    /// Named runtime adapters served over the shared base.
    registry: AdapterRegistry,
    /// Engine telemetry sink — every step ends by recording a
    /// [`TickRecord`] and refreshing the gauges.  Always present (a
    /// standalone scheduler gets its own), shared with the server's
    /// exposition threads via [`Scheduler::attach_obs`].
    obs: Arc<Telemetry>,
    /// Fault-injection plan (`--fault` / `REPRO_FAULT`); `None` when the
    /// harness is disarmed — the hot path then never consults it.
    fault: Option<Arc<crate::obs::FaultPlan>>,
    /// Disk tier (`--kv-spill`): spill file + parked sessions + the
    /// persistent prefix store.  `None` = memory-only (every tier hook
    /// below is a no-op and the scheduler is bitwise the pre-tier code).
    tier: Option<TieredKv>,
    /// Sequences preempted to the tier, in FIFO resume order.
    suspended: VecDeque<Suspended>,
}

impl<'m> Scheduler<'m> {
    pub fn new(model: &'m PackedModel, cfg: SchedConfig) -> Self {
        let pool = BlockPool::with_layout(
            model.cfg.n_layers,
            model.cfg.d_model,
            cfg.kv_block.max(1),
            cfg.blocks_total(),
            cfg.kv_layout(model.cfg.d_model / model.cfg.n_heads),
        );
        Scheduler {
            model,
            cfg,
            pending: VecDeque::new(),
            active: Vec::new(),
            pool,
            completed: 0,
            spec: None,
            registry: AdapterRegistry::new(model.cfg),
            obs: Telemetry::new(crate::obs::DEFAULT_TRACE_CAP),
            fault: None,
            tier: None,
            suspended: VecDeque::new(),
        }
    }

    /// Arm the fault-injection harness: the scheduler evaluates the
    /// `tick_panic` point per active sequence per tick, the target
    /// block pool evaluates `alloc` on every page allocation, and an
    /// attached tier evaluates `spill_io` on every slot read.
    pub fn set_fault(&mut self, plan: Arc<crate::obs::FaultPlan>) {
        self.pool.set_fault(plan.clone());
        if let Some(t) = self.tier.as_mut() {
            t.set_fault(plan.clone());
        }
        self.fault = Some(plan);
    }

    /// Attach the disk tier (`--kv-spill`).  Call before the first step;
    /// the tier inherits a previously armed fault plan.
    pub fn attach_tier(&mut self, mut tier: TieredKv) {
        if let Some(plan) = &self.fault {
            tier.set_fault(plan.clone());
        }
        self.tier = Some(tier);
    }

    /// Tier snapshot with the live suspended count filled in (`None`
    /// when no tier is attached).
    pub fn tier_stats(&self) -> Option<TierStats> {
        self.tier.as_ref().map(|t| {
            let mut s = t.stats();
            s.suspended = self.suspended.len();
            s
        })
    }

    /// Sequences currently parked on the disk tier.
    pub fn n_suspended(&self) -> usize {
        self.suspended.len()
    }

    /// The limits this scheduler admits against.
    pub fn config(&self) -> &SchedConfig {
        &self.cfg
    }

    /// Share telemetry with the serving layer (must be called before the
    /// first step — swapping mid-flight would reset every counter).
    pub fn attach_obs(&mut self, obs: Arc<Telemetry>) {
        self.obs = obs;
    }

    /// This scheduler's telemetry (metrics registry + tick-trace ring).
    pub fn obs(&self) -> &Arc<Telemetry> {
        &self.obs
    }

    /// The runtime adapter registry (stats frames, bench reports).
    pub fn adapters(&self) -> &AdapterRegistry {
        &self.registry
    }

    /// Mutable registry access for `adapter` load/unload commands.
    pub fn adapters_mut(&mut self) -> &mut AdapterRegistry {
        &mut self.registry
    }

    /// A scheduler that speculates: `draft` proposes `cfg.speculate`
    /// tokens per cycle and the target verifies them in one multi-token
    /// pass.  With `cfg.speculate == 0` the draft is ignored and this is
    /// exactly [`Scheduler::new`].  The draft's KV pages live in their
    /// own pool (budgeted by [`SchedConfig::draft_blocks_total`]) so
    /// drafting never competes with target KV for the serving budget.
    pub fn with_draft(model: &'m PackedModel, cfg: SchedConfig, draft: Arc<PackedModel>) -> Self {
        let mut s = Scheduler::new(model, cfg);
        if cfg.speculate > 0 {
            let pool = BlockPool::new(
                draft.cfg.n_layers,
                draft.cfg.d_model,
                cfg.kv_block.max(1),
                cfg.draft_blocks_total(),
            );
            s.spec = Some(SpecEngine {
                draft,
                pool,
                k: cfg.speculate,
                counters: Default::default(),
            });
        }
        s
    }

    /// Queue a request for admission at the next step.
    pub fn submit(&mut self, req: GenRequest) {
        self.pending.push_back(req);
    }

    /// Queue a request unless the admission queue is at its
    /// `max_pending` bound; an over-bound submission is handed back so
    /// the caller can answer an `overloaded` error frame instead of
    /// growing the queue without limit.
    pub fn try_submit(&mut self, req: GenRequest) -> std::result::Result<(), GenRequest> {
        if self.cfg.max_pending > 0 && self.pending.len() >= self.cfg.max_pending {
            self.obs.metrics.overload_rejections_total.inc();
            return Err(req);
        }
        self.pending.push_back(req);
        Ok(())
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty() || !self.suspended.is_empty()
    }

    pub fn n_active(&self) -> usize {
        self.active.len()
    }

    pub fn n_pending(&self) -> usize {
        self.pending.len()
    }

    pub fn n_completed(&self) -> usize {
        self.completed
    }

    /// KV memory snapshot (block counts, sharing, high-water marks).
    pub fn kv_stats(&self) -> KvStats {
        self.pool.stats()
    }

    /// The target block pool (read-only; the tier sizes its spill slots
    /// from the pool's page geometry).
    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// Speculative-decoding snapshot (`None` when not speculating):
    /// pool-wide proposal/acceptance counters plus the draft KV pool's
    /// block accounting.
    pub fn spec_stats(&self) -> Option<SpecStats> {
        self.spec.as_ref().map(|se| SpecStats {
            k: se.k,
            proposed: se.counters.proposed,
            accepted: se.counters.accepted,
            cycles: se.counters.cycles,
            fallbacks: se.counters.fallbacks,
            draft_kv: se.pool.stats(),
        })
    }

    /// Drop a request wherever it is (pending, mid-decode, or parked on
    /// the disk tier).  Active sequences are evicted at the next step
    /// with `Cancelled`.  A suspended sequence is settled here: it holds
    /// no pool pages, so a session-tagged one parks on the tier as-is
    /// (its slots are exactly the state a resume needs) and anything
    /// else frees its slots now.
    pub fn cancel(&mut self, key: u64) {
        self.pending.retain(|r| r.key != key);
        for r in self.active.iter_mut() {
            if r.req.key == key && r.finish.is_none() {
                r.finish = Some(FinishReason::Cancelled);
            }
        }
        let mut i = 0;
        while i < self.suspended.len() {
            if self.suspended[i].req.key != key {
                i += 1;
                continue;
            }
            let s = self.suspended.remove(i).expect("index in bounds");
            let tier = self.tier.as_mut().expect("suspended implies a tier");
            if let Some(sid) = s.req.session.clone() {
                tier.store_session(
                    sid,
                    SessionEntry {
                        tokens: s.tokens,
                        kv_len: s.kv_len,
                        slots: s.slots,
                        adapter: s.req.adapter.clone(),
                    },
                );
            } else {
                tier.free_slots(&s.slots);
            }
            if let Some(name) = s.req.adapter.as_deref() {
                self.registry.release(name);
            }
            self.completed += 1;
            if let Some(c) = self.obs.metrics.finished("cancelled") {
                c.inc();
            }
        }
    }

    /// Drop everything (engine shutdown), returning every block, spill
    /// slot, and adapter reference.
    pub fn clear(&mut self) {
        self.pending.clear();
        for r in self.active.iter_mut() {
            r.cache.release_all(&mut self.pool);
            if let (Some(d), Some(se)) = (r.draft.as_mut(), self.spec.as_mut()) {
                d.cache.release_all(&mut se.pool);
            }
            if let Some(name) = r.req.adapter.as_deref() {
                self.registry.release(name);
            }
        }
        self.active.clear();
        while let Some(s) = self.suspended.pop_front() {
            if let Some(tier) = self.tier.as_mut() {
                tier.free_slots(&s.slots);
            }
            if let Some(name) = s.req.adapter.as_deref() {
                self.registry.release(name);
            }
        }
    }

    /// Enforce deadlines at tick granularity: expired pending requests
    /// are rejected (they can no longer start in time), expired active
    /// sequences are marked to finish with `deadline` so this tick's
    /// eviction releases their pages.  A request without a deadline is
    /// never touched — the sweep is bitwise-invisible to deadline-free
    /// traffic.
    fn sweep_deadlines(&mut self, now: Instant, events: &mut Vec<StepEvent>) {
        let mut expired = 0u64;
        let mut rejected = 0u64;
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].deadline.is_some_and(|d| now >= d) {
                let req = self.pending.remove(i).expect("index in bounds");
                events.push(StepEvent::Rejected {
                    key: req.key,
                    id: req.id,
                    code: "deadline",
                    reason: "deadline expired before admission".to_string(),
                });
                expired += 1;
                rejected += 1;
            } else {
                i += 1;
            }
        }
        for r in self.active.iter_mut() {
            if r.finish.is_none() && r.req.deadline.is_some_and(|d| now >= d) {
                r.finish = Some(FinishReason::Deadline);
                expired += 1;
            }
        }
        if expired > 0 {
            self.obs.metrics.deadline_expirations_total.add(expired);
        }
        if rejected > 0 {
            self.obs.metrics.requests_rejected_total.add(rejected);
        }
    }

    /// Recover from a panic inside [`Scheduler::step`]: drop the
    /// offending sequence (`Some(key)`, attributed via
    /// [`crate::obs::SeqPanic`]) or — when the panic cannot be
    /// attributed — the whole batch, answer each victim an `internal`
    /// error frame, and rebuild a consistent view of the block pools and
    /// adapter registry from the surviving sequences' own block tables
    /// and routes.  Mid-step refcounts cannot be trusted after an
    /// unwind, so nothing is "released": the pools are recounted from
    /// scratch, which both reclaims the victims' pages and repairs any
    /// half-applied bookkeeping of the interrupted tick.  Healthy
    /// sequences keep their caches, sampler state, and token history
    /// untouched, so their streams continue bitwise unchanged.
    pub fn quarantine(&mut self, key: Option<u64>) -> Vec<StepEvent> {
        let mut events = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            let victim = match key {
                Some(k) => self.active[i].req.key == k,
                None => true,
            };
            if !victim {
                i += 1;
                continue;
            }
            let r = self.active.remove(i);
            self.obs.metrics.quarantines_total.inc();
            if let Some(c) = self.obs.metrics.finished("internal") {
                c.inc();
            }
            events.push(StepEvent::Rejected {
                key: r.req.key,
                id: r.req.id,
                code: "internal",
                reason: "sequence quarantined after engine panic".to_string(),
            });
            // The cache is dropped, not released: the rebuild below
            // recounts every page from the survivors.
        }
        self.pool.rebuild(self.active.iter().map(|r| r.cache.table()));
        if let Some(se) = self.spec.as_mut() {
            se.pool.rebuild(
                self.active.iter().filter_map(|r| r.draft.as_ref().map(|d| d.cache.table())),
            );
        }
        // Suspended sequences hold no pool pages (their state is spill
        // slots), so the pool rebuilds from active tables alone — but
        // they DO hold adapter references, which must survive the
        // registry recount or a parked route could unload mid-park.
        self.registry.rebuild_refs(
            self.active
                .iter()
                .filter_map(|r| r.req.adapter.as_deref())
                .chain(self.suspended.iter().filter_map(|s| s.req.adapter.as_deref())),
        );
        events
    }

    /// Longest shareable prompt prefix for `prompt` among live sequences
    /// and this tick's earlier admissions.  Returns positions to map.
    /// Active donors share any length (their rows are committed, so a
    /// partial tail page just copy-on-writes later); same-tick donors
    /// share only whole pages, so nobody writes into a page another
    /// staged sequence still has to fill.  Always leaves >= 1 prompt
    /// position to prefill — the request needs its own last-position
    /// logits.  Only same-adapter donors qualify (see [`same_adapter`]):
    /// K/V rows written under another adapter are not this sequence's.
    fn best_donor(
        &self,
        staged: &[Staged],
        prompt: &[i32],
        adapter: Option<&Arc<AdapterSet>>,
    ) -> (usize, Option<DonorRef>) {
        let cap = prompt.len() - 1;
        let bs = self.pool.block_size();
        let mut best = 0usize;
        let mut donor = None;
        for (i, r) in self.active.iter().enumerate() {
            if !same_adapter(r.adapter.as_ref(), adapter) {
                continue;
            }
            let s = common_prefix(prompt, &r.req.prompt).min(cap).min(r.cache.len());
            if s > best {
                best = s;
                donor = Some(DonorRef::Active(i));
            }
        }
        for (i, sgd) in staged.iter().enumerate() {
            if !same_adapter(sgd.adapter.as_ref(), adapter) {
                continue;
            }
            let aligned = (common_prefix(prompt, &sgd.req.prompt).min(cap) / bs) * bs;
            if aligned > best {
                best = aligned;
                donor = Some(DonorRef::Staged(i));
            }
        }
        (best, donor)
    }

    /// Move `active[i]` to the disk tier: export its block table to
    /// spill slots (pages sealed by the end-of-tick seal loop export
    /// compact), release its pool and draft pages, and park the rest.
    /// Returns `false` — leaving the sequence untouched — when the spill
    /// budget cannot cover its pages.
    fn suspend_active(&mut self, i: usize) -> bool {
        let tier = self.tier.as_mut().expect("suspend requires a tier");
        let n = self.active[i].cache.n_blocks();
        if n == 0 || !tier.can_spill(n) {
            return false;
        }
        let Ok(slots) = tier.spill_table(&self.pool, self.active[i].cache.table()) else {
            return false;
        };
        let mut r = self.active.remove(i);
        let kv_len = r.cache.len();
        r.cache.release_all(&mut self.pool);
        let draft_disabled = r.draft.as_ref().is_some_and(|d| d.disabled);
        if let (Some(d), Some(se)) = (r.draft.as_mut(), self.spec.as_mut()) {
            d.cache.release_all(&mut se.pool);
        }
        tier.note_preemption();
        self.suspended.push_back(Suspended {
            req: r.req,
            adapter: r.adapter,
            rng: r.rng,
            tokens: r.tokens,
            span: r.span,
            slots,
            kv_len,
            draft_disabled,
        });
        true
    }

    /// Preempt-to-spill: suspend the victim holding the most resident
    /// pages (ties: lowest key), freeing the largest chunk of budget per
    /// spill.  Returns `false` when no active sequence can be spilled.
    fn preempt_one(&mut self) -> bool {
        if self.tier.is_none() {
            return false;
        }
        let mut victim: Option<usize> = None;
        for (i, r) in self.active.iter().enumerate() {
            if r.finish.is_some() || r.cache.n_blocks() == 0 {
                continue;
            }
            let better = match victim {
                None => true,
                Some(v) => {
                    let (vb, vk) = (self.active[v].cache.n_blocks(), self.active[v].req.key);
                    r.cache.n_blocks() > vb || (r.cache.n_blocks() == vb && r.req.key < vk)
                }
            };
            if better {
                victim = Some(i);
            }
        }
        victim.is_some_and(|i| self.suspend_active(i))
    }

    /// Post-eviction sweep over decode-blocked sequences marked by the
    /// step loop: spill each to the tier, falling back to the classic
    /// `capacity` finish (drained at the NEXT step's eviction) when the
    /// spill budget is exhausted — so progress is guaranteed either way.
    fn suspend_marked(&mut self) {
        if self.tier.is_none() {
            return;
        }
        let mut i = 0;
        while i < self.active.len() {
            if !self.active[i].suspend {
                i += 1;
                continue;
            }
            self.active[i].suspend = false;
            if self.active[i].finish.is_some() {
                i += 1;
                continue;
            }
            if !self.suspend_active(i) {
                self.active[i].finish = Some(FinishReason::Capacity);
                i += 1;
            }
        }
    }

    /// Resume suspended sequences (FIFO) while the batch and pool have
    /// room.  A sequence that can never fit the pool again finishes
    /// `capacity`, an expired one finishes `deadline`, and a failed
    /// restore (bad CRC, I/O error, injected `spill_io` fault) answers
    /// an `internal` error frame — each contained to the one sequence.
    fn resume_suspended(&mut self, events: &mut Vec<StepEvent>) {
        if self.tier.is_none() {
            return;
        }
        let now = Instant::now();
        while !self.suspended.is_empty() && self.active.len() < self.cfg.max_batch {
            let bs = self.pool.block_size();
            let front = self.suspended.front().expect("checked non-empty");
            if (front.kv_len + 1).div_ceil(bs) > self.pool.max_blocks() {
                let s = self.suspended.pop_front().expect("non-empty");
                self.finish_suspended(s, FinishReason::Capacity, events);
                continue;
            }
            if front.req.deadline.is_some_and(|d| now >= d) {
                self.obs.metrics.deadline_expirations_total.inc();
                let s = self.suspended.pop_front().expect("non-empty");
                self.finish_suspended(s, FinishReason::Deadline, events);
                continue;
            }
            // Room for the restored table plus the next decode page —
            // resuming into an instant reserve miss would just thrash
            // the file.  Strict FIFO: if the front doesn't fit, nobody
            // behind it jumps the line (no starvation).
            let need = front.slots.len().max((front.kv_len + 1).div_ceil(bs));
            if self.pool.available() < need {
                break;
            }
            let s = self.suspended.pop_front().expect("non-empty");
            let tier = self.tier.as_mut().expect("resume requires a tier");
            match tier.restore_table(&mut self.pool, &s.slots, true) {
                Ok(table) => {
                    tier.note_resume();
                    let cache = PagedKvCache::from_parts(&self.pool, table, s.kv_len);
                    let draft = if s.adapter.is_none() {
                        self.spec.as_ref().map(|se| {
                            let mut d = DraftState::new(&se.pool);
                            d.disabled = s.draft_disabled;
                            d
                        })
                    } else {
                        None
                    };
                    self.active.push(Running {
                        req: s.req,
                        adapter: s.adapter,
                        cache,
                        rng: s.rng,
                        tokens: s.tokens,
                        span: s.span,
                        finish: None,
                        draft,
                        suspend: false,
                        prefix_published: 0,
                    });
                }
                Err(e) => {
                    tier.free_slots(&s.slots);
                    if let Some(name) = s.req.adapter.as_deref() {
                        self.registry.release(name);
                    }
                    if let Some(c) = self.obs.metrics.finished("internal") {
                        c.inc();
                    }
                    events.push(StepEvent::Rejected {
                        key: s.req.key,
                        id: s.req.id,
                        code: "internal",
                        reason: format!("suspended sequence failed to restore: {e}"),
                    });
                }
            }
        }
    }

    /// Terminally finish a sequence straight from the suspended set:
    /// free its slots, release its adapter reference, and emit `Done`
    /// (the stream keeps every token already emitted).
    fn finish_suspended(
        &mut self,
        s: Suspended,
        finish: FinishReason,
        events: &mut Vec<StepEvent>,
    ) {
        if let Some(tier) = self.tier.as_mut() {
            tier.free_slots(&s.slots);
        }
        if let Some(name) = s.req.adapter.as_deref() {
            self.registry.release(name);
        }
        let done_at = Instant::now();
        let stats = RequestStats {
            queue_secs: s.span.queue_secs(),
            prefill_secs: s.span.prefill_secs,
            total_secs: s.span.total_secs(done_at),
            max_inter_token_secs: s.span.max_gap_secs,
            n_new_tokens: s.span.emitted,
            shared_prefix_tokens: s.span.shared_prefix_tokens,
            spec_proposed: s.span.spec_proposed,
            spec_accepted: s.span.spec_accepted,
        };
        self.completed += 1;
        let m = &self.obs.metrics;
        if let Some(c) = m.finished(finish.as_str()) {
            c.inc();
        }
        m.queue_seconds.observe(stats.queue_secs);
        m.request_seconds.observe(stats.total_secs);
        m.prefill_seconds.observe(stats.prefill_secs);
        events.push(StepEvent::Done {
            key: s.req.key,
            id: s.req.id,
            tokens: s.tokens,
            prompt_len: s.req.prompt.len(),
            finish,
            stats,
        });
    }

    /// Session resume at admission: when the request names a parked
    /// session whose stored history is a strict prefix of the new prompt
    /// (same adapter route), restore its pages and share `kv_len`
    /// positions — the prefill below touches only the new suffix.  Any
    /// mismatch — different route, prompt not extending the history, no
    /// pool room right now, or a failed restore — falls back to a fresh
    /// prefill (the parked entry survives except on restore failure,
    /// where its slots are freed).
    fn try_resume_session(&mut self, req: &GenRequest) -> Option<(PagedKvCache, usize)> {
        let tier = self.tier.as_mut()?;
        let sid = req.session.as_deref()?;
        {
            let e = tier.session(sid)?;
            if e.adapter != req.adapter
                || e.kv_len == 0
                || e.kv_len >= req.prompt.len()
                || req.prompt[..e.kv_len] != e.tokens[..e.kv_len]
                || self.pool.available() < e.slots.len()
            {
                return None;
            }
        }
        let e = tier.take_session(sid).expect("session peeked above");
        match tier.restore_table(&mut self.pool, &e.slots, true) {
            Ok(table) => Some((PagedKvCache::from_parts(&self.pool, table, e.kv_len), e.kv_len)),
            Err(_) => {
                tier.free_slots(&e.slots);
                None
            }
        }
    }

    /// Prefix-store promotion at admission: match the prompt's leading
    /// pages against the persistent store and, when the stored run beats
    /// every live donor (`beat` positions), restore it into fresh pool
    /// pages.  Whole pages only (the promoted tail page may be sealed —
    /// writes always land in a fresh page past it), and the slots stay
    /// live: prefix records are read-shared forever.  Adapter-routed
    /// requests never consult the store — its pages were written under
    /// the default route.
    fn try_promote_prefix(
        &mut self,
        req: &GenRequest,
        beat: usize,
    ) -> Option<(PagedKvCache, usize)> {
        if req.adapter.is_some() {
            return None;
        }
        let bs = self.pool.block_size();
        let tier = self.tier.as_mut()?;
        if !tier.prefix_enabled() {
            return None;
        }
        let slots = tier.prefix_match(&req.prompt, bs);
        let pages = slots.len().min((req.prompt.len() - 1) / bs);
        if pages == 0 {
            return None;
        }
        let positions = pages * bs;
        if positions <= beat || self.pool.available() < pages {
            return None;
        }
        let t0 = Instant::now();
        let table = tier.restore_table(&mut self.pool, &slots[..pages], false).ok()?;
        let secs = t0.elapsed().as_secs_f64();
        tier.note_promote(secs);
        self.obs.metrics.tier_promote_seconds.observe(secs);
        Some((PagedKvCache::from_parts(&self.pool, table, positions), positions))
    }

    /// Publish each running sequence's newly committed whole prompt
    /// pages into the persistent prefix store (runs after the seal loop,
    /// so quantized layouts publish sealed pages).  The per-sequence
    /// high-water mark keeps the walk a no-op once a prompt is covered.
    fn publish_prefixes(&mut self) {
        let Some(tier) = self.tier.as_mut() else { return };
        if !tier.prefix_enabled() {
            return;
        }
        let bs = self.pool.block_size();
        for r in self.active.iter_mut() {
            if r.req.adapter.is_some() {
                continue;
            }
            let pages = (r.req.prompt.len() / bs).min(r.cache.len() / bs);
            if pages > r.prefix_published {
                r.prefix_published =
                    tier.publish_prefix(&self.pool, &r.req.prompt, r.cache.table(), pages);
            }
        }
    }

    /// Admit pending requests while the batch has room and the block
    /// budget covers their prompts, then prefill every admission of the
    /// tick in one batched pass and emit first tokens.  Queue triage is
    /// charged to the tick's `admit` phase, the batched prompt pass plus
    /// first-token sampling to `prefill`.
    fn admit(&mut self, events: &mut Vec<StepEvent>, rec: &mut TickRecord) -> Result<()> {
        let t_admit = Instant::now();
        let n_rejected_before = events.len();
        let mut staged: Vec<Staged> = Vec::new();
        while self.active.len() + staged.len() < self.cfg.max_batch {
            let Some(mut req) = self.pending.pop_front() else { break };
            if req.deadline.is_some_and(|d| t_admit >= d) {
                self.obs.metrics.deadline_expirations_total.inc();
                events.push(StepEvent::Rejected {
                    key: req.key,
                    id: req.id,
                    code: "deadline",
                    reason: "deadline expired before admission".to_string(),
                });
                continue;
            }
            if req.prompt.is_empty() {
                events.push(StepEvent::Rejected {
                    key: req.key,
                    id: req.id,
                    code: "bad_request",
                    reason: "empty prompt".to_string(),
                });
                continue;
            }
            if req.prompt.len() > self.cfg.max_prompt {
                events.push(StepEvent::Rejected {
                    key: req.key,
                    id: req.id,
                    code: "bad_request",
                    reason: format!(
                        "prompt length {} > max {}",
                        req.prompt.len(),
                        self.cfg.max_prompt
                    ),
                });
                continue;
            }
            if req.max_new > self.cfg.max_new_cap {
                events.push(StepEvent::Rejected {
                    key: req.key,
                    id: req.id,
                    code: "bad_request",
                    reason: format!(
                        "max_new {} > server cap {} (--max-new-cap)",
                        req.max_new, self.cfg.max_new_cap
                    ),
                });
                continue;
            }
            req.max_new = req.max_new.max(1);

            // Resolve + refcount the routed adapter.  Unknown (or
            // draining) names reject here — the client gets an error
            // frame instead of silently falling back to another task's
            // weights.
            let adapter = match req.adapter.as_deref() {
                None => None,
                Some(name) => match self.registry.acquire(name) {
                    Ok(set) => Some(set),
                    Err(e) => {
                        events.push(StepEvent::Rejected {
                            key: req.key,
                            id: req.id,
                            code: "bad_request",
                            reason: e.to_string(),
                        });
                        continue;
                    }
                },
            };

            // Tier first: a session-tagged request whose prompt extends
            // its parked history resumes from spilled pages (zero
            // re-prefill of the shared positions); otherwise a
            // prefix-store match promotes published pages from disk when
            // it beats every live donor.  No tier (or no hit): the
            // classic live-donor fork.
            let (mut cache, shared) = match self.try_resume_session(&req) {
                Some(hit) => hit,
                None => {
                    let (shared, donor) = self.best_donor(&staged, &req.prompt, adapter.as_ref());
                    match self.try_promote_prefix(&req, shared) {
                        Some(hit) => hit,
                        None => {
                            let cache = match donor {
                                Some(DonorRef::Active(i)) => PagedKvCache::fork_prefix(
                                    &self.active[i].cache,
                                    shared,
                                    &mut self.pool,
                                )?,
                                Some(DonorRef::Staged(i)) => PagedKvCache::fork_prefix(
                                    &staged[i].cache,
                                    shared,
                                    &mut self.pool,
                                )?,
                                None => PagedKvCache::new(&self.pool),
                            };
                            (cache, shared)
                        }
                    }
                }
            };
            // Admission by block budget: the prompt must get its pages
            // now (decode pages grow on demand later).  With a tier
            // attached, exhaustion preempts the coldest active sequence
            // to disk and retries (as long as the prompt can fit the
            // pool at all).  Otherwise — or when nothing is left to
            // spill — the request backs off at the FRONT of the queue;
            // arrival order is preserved and a later eviction lets it
            // in.  If nothing is running (or staged) the pool will never
            // free up, so a prompt that doesn't fit an idle pool is
            // rejected outright instead of livelocking the queue.
            let mut reserved = cache.reserve(req.prompt.len(), &mut self.pool).is_ok();
            if !reserved
                && self.tier.is_some()
                && req.prompt.len().div_ceil(self.pool.block_size()) <= self.pool.max_blocks()
            {
                while !reserved && self.preempt_one() {
                    reserved = cache.reserve(req.prompt.len(), &mut self.pool).is_ok();
                }
            }
            if !reserved {
                cache.release_all(&mut self.pool);
                // Balance the acquire above: a backed-off request
                // re-acquires when it re-admits; a rejected one never
                // enters the batch.
                if let Some(name) = req.adapter.as_deref() {
                    self.registry.release(name);
                }
                if self.active.is_empty() && staged.is_empty() {
                    events.push(StepEvent::Rejected {
                        key: req.key,
                        id: req.id,
                        code: "bad_request",
                        reason: format!(
                            "prompt needs {} KV blocks, pool budget is {}",
                            req.prompt.len().div_ceil(self.pool.block_size()),
                            self.pool.max_blocks()
                        ),
                    });
                    continue;
                }
                self.pending.push_front(req);
                break;
            }
            staged.push(Staged { req, adapter, cache, admitted_at: Instant::now(), shared });
        }
        rec.phase_ns[PH_ADMIT] += t_admit.elapsed().as_nanos() as u64;
        let rejected = (events.len() - n_rejected_before) as u64;
        if rejected > 0 {
            self.obs.metrics.requests_rejected_total.add(rejected);
        }
        if staged.is_empty() {
            return Ok(());
        }
        rec.admitted += staged.len();
        self.obs.metrics.requests_admitted_total.add(staged.len() as u64);

        // -- ONE batched prefill over every admission of this tick --
        let t0 = Instant::now();
        let suffixes: Vec<Vec<i32>> =
            staged.iter().map(|s| s.req.prompt[s.cache.len()..].to_vec()).collect();
        let sfx: Vec<&[i32]> = suffixes.iter().map(|v| &v[..]).collect();
        let prefilled = {
            // Effective set per sequence: the routed adapter, else the
            // model's default path — exactly what the un-suffixed
            // wrappers would pass, so an unrouted batch is bitwise the
            // pre-registry code path.  Arcs are cloned out first so the
            // set refs don't hold `staged` borrowed against the caches.
            let arcs: Vec<Option<Arc<AdapterSet>>> =
                staged.iter().map(|s| s.adapter.clone()).collect();
            let sets: Vec<Option<&AdapterSet>> = arcs
                .iter()
                .map(|a| a.as_deref().or(self.model.default_adapter.as_deref()))
                .collect();
            let mut caches: Vec<&mut PagedKvCache> =
                staged.iter_mut().map(|s| &mut s.cache).collect();
            self.model.prefill_batch_with(&sfx, &mut caches, &mut self.pool, &sets)
        };
        let logits = match prefilled {
            Ok(l) => l,
            Err(e) => {
                // Model-level failure: reclaim the staged pages before
                // surfacing it (the engine resets the batch).
                for s in staged.iter_mut() {
                    s.cache.release_all(&mut self.pool);
                    if let Some(name) = s.req.adapter.as_deref() {
                        self.registry.release(name);
                    }
                }
                return Err(e);
            }
        };
        let prefill_secs = t0.elapsed().as_secs_f64();
        let now = Instant::now();
        for (bi, sgd) in staged.into_iter().enumerate() {
            let Staged { req, adapter, cache, admitted_at, shared } = sgd;
            let mut rng = req.sampling.map(|p| seq_rng(p.seed, 0));
            let tok = pick(logits.row(bi), req.sampling.as_ref(), rng.as_mut());
            let mut run = Running {
                tokens: {
                    let mut t = req.prompt.clone();
                    t.push(tok);
                    t
                },
                cache,
                rng,
                span: RequestSpan::admitted(req.queued_at, admitted_at, prefill_secs, shared, now),
                finish: None,
                // Adapter-routed sequences take the plain decode path —
                // the draft model has no notion of per-request adapters,
                // so its proposals would come from the wrong
                // distribution.  Chosen (and pinned by tests) over
                // threading adapters through the draft.
                draft: if adapter.is_none() {
                    self.spec.as_ref().map(|se| DraftState::new(&se.pool))
                } else {
                    None
                },
                suspend: false,
                prefix_published: 0,
                adapter,
                req,
            };
            events.push(StepEvent::Token {
                key: run.req.key,
                id: run.req.id.clone(),
                index: 0,
                token: tok,
            });
            run.check_finished(tok);
            self.active.push(run);
        }
        rec.phase_ns[PH_PREFILL] += t0.elapsed().as_nanos() as u64;
        Ok(())
    }

    /// One scheduler step: admit (batched prefill), then decode — a
    /// draft/verify speculative cycle for sequences that can speculate
    /// (emitting 1..=k+1 tokens each), one plain batched step for the
    /// rest — and evict finished sequences.  Returns events in emission
    /// order.
    pub fn step(&mut self) -> Result<Vec<StepEvent>> {
        let tick0 = Instant::now();
        let mut rec = TickRecord::default();
        let kv_before = self.pool.stats().resident_blocks as i64;
        let prof_before = if profile::enabled() { Some(profile::snapshot()) } else { None };
        let spec_before = self.spec.as_ref().map(|se| se.counters);

        let mut events = Vec::new();
        self.sweep_deadlines(tick0, &mut events);
        // Suspended sequences resume BEFORE new admissions — they are
        // older, and their restored pages must not be raced away by
        // this tick's prompts.
        let t_tier = Instant::now();
        self.resume_suspended(&mut events);
        rec.phase_ns[PH_TIER] += t_tier.elapsed().as_nanos() as u64;
        self.admit(&mut events, &mut rec)?;
        rec.batch = self.active.len();
        rec.pending = self.pending.len();

        // -- speculative draft/verify cycle (marks handled sequences) --
        let handled = match self.spec.as_mut() {
            Some(se) => Self::spec_cycle(
                self.model,
                &mut self.active,
                &mut self.pool,
                se,
                &mut events,
                &mut rec,
            )?,
            None => vec![false; self.active.len()],
        };

        // -- one batched decode step over sequences still running --
        let mut idxs: Vec<usize> = Vec::new();
        let mut toks: Vec<i32> = Vec::new();
        let mut picked: Vec<(usize, i32)> = Vec::new();
        {
            let t_dec = Instant::now();
            let mut caches: Vec<&mut PagedKvCache> = Vec::new();
            let mut rngs: Vec<&mut Option<Rng>> = Vec::new();
            let mut samplings: Vec<Option<SamplingParams>> = Vec::new();
            let mut adps: Vec<Option<Arc<AdapterSet>>> = Vec::new();
            let mut capacity_hit = false;
            for (i, r) in self.active.iter_mut().enumerate() {
                if r.finish.is_none() && !handled[i] {
                    // Fault harness: the per-sequence tick checkpoint.
                    // Panics with a SeqPanic payload naming this
                    // sequence; the engine catches it and quarantines
                    // exactly this sequence.
                    if let Some(f) = &self.fault {
                        crate::obs::fault::maybe_tick_panic(f, r.req.key);
                    }
                    // Grow this sequence's table by (at most) one page
                    // up front so a budget miss finishes ONE sequence
                    // with `capacity` instead of failing the batch.
                    // Only the FIRST miss of a step finishes: its pages
                    // are released at this step's eviction, so later
                    // missers just skip this step and usually continue
                    // on the reclaimed pages (one finish per step also
                    // guarantees progress).
                    let upto = r.cache.len() + 1;
                    if r.cache.reserve(upto, &mut self.pool).is_err() {
                        if self.tier.is_some() {
                            // Tier: suspend instead of finishing — the
                            // post-eviction sweep spills this sequence's
                            // pages (falling back to the capacity finish
                            // only if the spill budget is exhausted).
                            r.suspend = true;
                        } else if !capacity_hit {
                            capacity_hit = true;
                            r.finish = Some(FinishReason::Capacity);
                        }
                        continue;
                    }
                    idxs.push(i);
                    toks.push(*r.tokens.last().expect("active sequence has tokens"));
                    samplings.push(r.req.sampling);
                    adps.push(r.adapter.clone());
                    let Running { cache, rng, .. } = r;
                    caches.push(cache);
                    rngs.push(rng);
                }
            }
            if !idxs.is_empty() {
                // The mixed-adapter batched step: ONE shared base pass,
                // per-sequence deltas grouped by adapter identity inside.
                let sets: Vec<Option<&AdapterSet>> = adps
                    .iter()
                    .map(|a| a.as_deref().or(self.model.default_adapter.as_deref()))
                    .collect();
                let logits =
                    self.model.forward_step_paged_with(&toks, &mut caches, &mut self.pool, &sets)?;
                rec.phase_ns[PH_DECODE] += t_dec.elapsed().as_nanos() as u64;
                let t_smp = Instant::now();
                for (j, &i) in idxs.iter().enumerate() {
                    let tok = pick(logits.row(j), samplings[j].as_ref(), rngs[j].as_mut());
                    picked.push((i, tok));
                }
                rec.phase_ns[PH_SAMPLE] += t_smp.elapsed().as_nanos() as u64;
            } else {
                rec.phase_ns[PH_DECODE] += t_dec.elapsed().as_nanos() as u64;
            }
        }
        let t_emit = Instant::now();
        let now = Instant::now();
        for (i, tok) in picked {
            self.active[i].emit_token(tok, now, &mut events);
        }

        // -- per-adapter token accounting (every emitter of this step is
        //    still in `active`; eviction below only re-packages already
        //    counted tokens) --
        for ev in &events {
            if let StepEvent::Token { key, .. } = ev {
                let name = self
                    .active
                    .iter()
                    .find(|r| r.req.key == *key)
                    .and_then(|r| r.req.adapter.as_deref());
                if name.is_some() {
                    self.obs.metrics.adapter_tokens_total.inc();
                } else {
                    self.obs.metrics.baseline_tokens_total.inc();
                }
                self.registry.count_tokens(name, 1);
                rec.tokens += 1;
            }
        }

        // -- evict finished sequences (stable order), reclaim blocks --
        let mut kept = Vec::with_capacity(self.active.len());
        for mut r in self.active.drain(..) {
            match r.finish {
                None => kept.push(r),
                Some(finish) => {
                    let done_at = Instant::now();
                    let stats = RequestStats {
                        queue_secs: r.span.queue_secs(),
                        prefill_secs: r.span.prefill_secs,
                        total_secs: r.span.total_secs(done_at),
                        max_inter_token_secs: r.span.max_gap_secs,
                        n_new_tokens: r.span.emitted,
                        shared_prefix_tokens: r.span.shared_prefix_tokens,
                        spec_proposed: r.span.spec_proposed,
                        spec_accepted: r.span.spec_accepted,
                    };
                    self.completed += 1;
                    rec.finished += 1;
                    let m = &self.obs.metrics;
                    if let Some(c) = m.finished(finish.as_str()) {
                        c.inc();
                    }
                    m.queue_seconds.observe(stats.queue_secs);
                    m.request_seconds.observe(stats.total_secs);
                    m.prefill_seconds.observe(stats.prefill_secs);
                    // Tier: park a finished session's KV verbatim so a
                    // later request with the same id continues without
                    // re-prefilling.  Capacity/deadline exits don't park
                    // — those budgets are genuinely spent.
                    if let (Some(tier), Some(sid)) = (self.tier.as_mut(), r.req.session.clone()) {
                        if matches!(
                            finish,
                            FinishReason::Length | FinishReason::Stop | FinishReason::Cancelled
                        ) && r.cache.n_blocks() > 0
                            && tier.can_spill(r.cache.n_blocks())
                        {
                            if let Ok(slots) = tier.spill_table(&self.pool, r.cache.table()) {
                                tier.store_session(
                                    sid,
                                    SessionEntry {
                                        tokens: r.tokens.clone(),
                                        kv_len: r.cache.len(),
                                        slots,
                                        adapter: r.req.adapter.clone(),
                                    },
                                );
                            }
                        }
                    }
                    r.cache.release_all(&mut self.pool);
                    if let (Some(d), Some(se)) = (r.draft.as_mut(), self.spec.as_mut()) {
                        d.cache.release_all(&mut se.pool);
                    }
                    if let Some(name) = r.req.adapter.as_deref() {
                        self.registry.release(name);
                    }
                    events.push(StepEvent::Done {
                        key: r.req.key,
                        id: r.req.id,
                        tokens: r.tokens,
                        prompt_len: r.req.prompt.len(),
                        finish,
                        stats,
                    });
                }
            }
        }
        self.active = kept;
        rec.phase_ns[PH_EMIT] += t_emit.elapsed().as_nanos() as u64;

        // Quantized layouts: seal fully-committed pages at end of tick.
        // This runs AFTER spec rollback and eviction, so every row inside
        // a sealed page is accepted-final — speculative truncation never
        // has to reopen a page mid-cycle.  No-op under the f32 layout.
        for r in &self.active {
            r.cache.seal_committed(&mut self.pool);
        }

        // -- disk tier: spill decode-blocked sequences (after the seal
        //    loop, so quantized pages export compact) and publish newly
        //    sealed prompt pages to the prefix store --
        if self.tier.is_some() {
            let t_tier = Instant::now();
            self.suspend_marked();
            self.publish_prefixes();
            rec.phase_ns[PH_TIER] += t_tier.elapsed().as_nanos() as u64;
        }

        self.finish_tick(&mut rec, kv_before, spec_before, prof_before, tick0);
        Ok(events)
    }

    /// Close out one tick's telemetry: KV/queue gauges, spec and kernel
    /// deltas, tick histograms, and the trace-ring append.
    fn finish_tick(
        &self,
        rec: &mut TickRecord,
        kv_before: i64,
        spec_before: Option<crate::serve::spec::SpecCounters>,
        prof_before: Option<[profile::KernelCounts; profile::N_KINDS]>,
        tick0: Instant,
    ) {
        let kv = self.pool.stats();
        rec.kv_resident = kv.resident_blocks;
        rec.kv_delta = kv.resident_blocks as i64 - kv_before;
        if let (Some(se), Some(before)) = (self.spec.as_ref(), spec_before) {
            rec.spec_proposed = se.counters.proposed - before.proposed;
            rec.spec_accepted = se.counters.accepted - before.accepted;
            let m = &self.obs.metrics;
            m.spec_proposed_total.add(rec.spec_proposed as u64);
            m.spec_accepted_total.add(rec.spec_accepted as u64);
            m.spec_cycles_total.add((se.counters.cycles - before.cycles) as u64);
            m.spec_fallbacks_total.add((se.counters.fallbacks - before.fallbacks) as u64);
        }
        if let Some(before) = prof_before {
            let after = profile::snapshot();
            for (i, kind) in profile::KIND_NAMES.iter().enumerate() {
                let calls = after[i].calls - before[i].calls;
                if calls > 0 {
                    rec.kernels.push(KernelTickDelta {
                        kind: kind.to_string(),
                        calls,
                        ns: after[i].ns - before[i].ns,
                        flops: after[i].flops - before[i].flops,
                    });
                }
            }
        }
        let m = &self.obs.metrics;
        m.kv_blocks_resident.set(kv.resident_blocks as i64);
        m.kv_blocks_free.set(kv.free_blocks as i64);
        m.kv_blocks_shared.set(kv.shared_blocks as i64);
        m.kv_blocks_limit.set(kv.blocks_total as i64);
        m.kv_bytes_resident.set(kv.resident_bytes as i64);
        m.kv_bytes_peak.set(kv.peak_resident_bytes as i64);
        m.active_sequences.set(self.active.len() as i64);
        m.pending_requests.set(self.pending.len() as i64);
        m.adapters_registered.set(self.registry.len() as i64);
        if let Some(t) = self.tier.as_ref() {
            let ts = t.stats();
            m.tier_blocks_spilled.set(ts.spilled_blocks as i64);
            m.tier_bytes_spilled.set(ts.spilled_bytes as i64);
            m.tier_spill_writes.set(ts.spill_writes as i64);
            m.tier_spill_reads.set(ts.spill_reads as i64);
            m.tier_preemptions.set(ts.preemptions as i64);
            m.tier_resumes.set(ts.resumes as i64);
            m.tier_suspended.set(self.suspended.len() as i64);
            m.tier_restores.set(ts.block_restores as i64);
            m.tier_restore_failures.set(ts.restore_failures as i64);
            m.tier_sessions_stored.set(ts.sessions_stored as i64);
            m.tier_session_resumes.set(ts.session_resumes as i64);
            m.tier_prefix_pages.set(ts.prefix_pages as i64);
            m.tier_prefix_hits.set(ts.prefix_hits as i64);
            m.tier_prefix_misses.set(ts.prefix_misses as i64);
        }
        m.ticks_total.inc();
        m.tokens_emitted_total.add(rec.tokens as u64);
        m.batch_size.observe(rec.batch as f64);
        m.tick_seconds.observe(tick0.elapsed().as_secs_f64());
        for (h, &ns) in m.tick_phase_seconds.iter().zip(rec.phase_ns.iter()) {
            if ns > 0 {
                h.observe(ns as f64 / 1e9);
            }
        }
        self.obs.record_tick(std::mem::take(rec));
    }

    /// One speculative draft/verify cycle over every sequence that can
    /// speculate this tick.  Drafting is batched on the draft model
    /// (ragged catch-up prefill + shrinking single-token steps), then
    /// the target verifies ALL sequences' chunks in ONE
    /// [`PackedModel::forward_verify_paged`] pass; acceptance walks each
    /// sequence's rows with its own sampler stream, rejected positions
    /// are popped with [`PagedKvCache::truncate`].  Returns a mask of
    /// sequences this cycle stepped — the plain decode loop takes the
    /// rest (no draft state, speculation disabled, last-token requests,
    /// or a target-pool reserve miss, which the plain path resolves with
    /// its capacity-finish logic).
    fn spec_cycle(
        model: &PackedModel,
        active: &mut [Running],
        pool: &mut BlockPool,
        se: &mut SpecEngine,
        events: &mut Vec<StepEvent>,
        rec: &mut TickRecord,
    ) -> Result<Vec<bool>> {
        let t_draft = Instant::now();
        let n = active.len();
        let mut handled = vec![false; n];
        // -- pass A: eligibility + capacity reservations --
        // ks[i] > 0 marks sequence i speculating this tick with that k.
        let mut ks = vec![0usize; n];
        for (i, r) in active.iter_mut().enumerate() {
            if r.finish.is_some() {
                continue;
            }
            // Adapter-routed sequences never get draft state (admission
            // leaves it `None`): they fall through to the plain batched
            // step, which threads their adapter.
            let Some(d) = r.draft.as_mut() else { continue };
            if d.disabled {
                continue;
            }
            let remaining = r.req.max_new.saturating_sub(r.span.emitted);
            if remaining < 2 {
                // A single pending token gains nothing from drafting.
                continue;
            }
            let k_eff = se.k.min(remaining - 1);
            let t = r.tokens.len();
            // Draft capacity for catch-up + k-1 proposal steps; a miss
            // permanently falls this sequence back to plain decode.
            if d.cache.reserve(t + k_eff - 1, &mut se.pool).is_err() {
                d.cache.release_all(&mut se.pool);
                d.disabled = true;
                se.counters.fallbacks += 1;
                continue;
            }
            // Target capacity for the whole verify chunk (CoW of shared
            // tails happens here); a miss skips speculation this tick —
            // the plain loop still tries the single-position step and
            // owns the capacity-finish policy.  Blocks the failed
            // multi-page reserve DID acquire are returned immediately so
            // speculation never deepens pool pressure for other
            // sequences (a plain single-position reserve can't strand).
            if r.cache.reserve(r.cache.len() + k_eff + 1, pool).is_err() {
                r.cache.trim_reserve(pool);
                continue;
            }
            ks[i] = k_eff;
        }
        if ks.iter().all(|&k| k == 0) {
            rec.phase_ns[PH_DRAFT] += t_draft.elapsed().as_nanos() as u64;
            return Ok(handled);
        }

        // -- draft catch-up: one ragged prefill over every speculator's
        //    unseen tokens, whose last rows seed the first proposals --
        let mut sfx_owned: Vec<Vec<i32>> = Vec::new();
        let mut order: Vec<usize> = Vec::new();
        for (i, r) in active.iter().enumerate() {
            if ks[i] == 0 {
                continue;
            }
            let dlen = r.draft.as_ref().expect("speculator has draft state").cache.len();
            sfx_owned.push(r.tokens[dlen..].to_vec());
            order.push(i);
        }
        let dlogits = {
            let sfx: Vec<&[i32]> = sfx_owned.iter().map(|v| &v[..]).collect();
            let mut dcaches: Vec<&mut PagedKvCache> = Vec::new();
            for (i, r) in active.iter_mut().enumerate() {
                if ks[i] > 0 {
                    dcaches.push(&mut r.draft.as_mut().expect("draft state").cache);
                }
            }
            se.draft.prefill_batch(&sfx, &mut dcaches, &mut se.pool)?
        };
        let mut proposals: Vec<Vec<i32>> =
            (0..order.len()).map(|j| vec![argmax(dlogits.row(j)) as i32]).collect();

        // -- remaining draft steps, batch shrinking as per-sequence k
        //    budgets run out --
        let max_k = order.iter().map(|&i| ks[i]).max().unwrap_or(1);
        for step in 1..max_k {
            let mut toks: Vec<i32> = Vec::new();
            let mut live: Vec<usize> = Vec::new();
            let mut caches: Vec<&mut PagedKvCache> = Vec::new();
            let mut j = 0usize;
            for (i, r) in active.iter_mut().enumerate() {
                if ks[i] == 0 {
                    continue;
                }
                if ks[i] > step {
                    toks.push(*proposals[j].last().expect("non-empty proposals"));
                    caches.push(&mut r.draft.as_mut().expect("draft state").cache);
                    live.push(j);
                }
                j += 1;
            }
            if toks.is_empty() {
                break;
            }
            let dl = se.draft.forward_step_paged(&toks, &mut caches, &mut se.pool)?;
            drop(caches);
            for (row, &j) in live.iter().enumerate() {
                proposals[j].push(argmax(dl.row(row)) as i32);
            }
        }

        rec.phase_ns[PH_DRAFT] += t_draft.elapsed().as_nanos() as u64;

        // -- ONE multi-sequence multi-position verify pass --
        let t_verify = Instant::now();
        let chunks: Vec<Vec<i32>> = order
            .iter()
            .zip(&proposals)
            .map(|(&i, props)| {
                let mut c = vec![*active[i].tokens.last().expect("active sequence has tokens")];
                c.extend_from_slice(props);
                c
            })
            .collect();
        let vlogits = {
            let refs: Vec<&[i32]> = chunks.iter().map(|v| &v[..]).collect();
            let mut tcaches: Vec<&mut PagedKvCache> = Vec::new();
            for (i, r) in active.iter_mut().enumerate() {
                if ks[i] > 0 {
                    tcaches.push(&mut r.cache);
                }
            }
            model.forward_verify_paged(&refs, &mut tcaches, pool)?
        };
        rec.phase_ns[PH_VERIFY] += t_verify.elapsed().as_nanos() as u64;

        // -- acceptance + KV rollback, sequence by sequence --
        let t_accept = Instant::now();
        let now = Instant::now();
        let mut row0 = 0usize;
        for (j, &i) in order.iter().enumerate() {
            let r = &mut active[i];
            let remaining = r.req.max_new - r.span.emitted;
            let (emitted, acc) = accept_tokens(
                &vlogits,
                row0,
                &proposals[j],
                r.req.sampling.as_ref(),
                r.rng.as_mut(),
                remaining,
                r.req.stop,
            );
            row0 += chunks[j].len();
            se.counters.proposed += proposals[j].len();
            se.counters.accepted += acc;
            se.counters.cycles += 1;
            r.span.spec_proposed += proposals[j].len();
            r.span.spec_accepted += acc;
            for &tok in &emitted {
                r.emit_token(tok, now, events);
            }
            // Pop the rejected positions; the draft may legitimately sit
            // one position behind (all-accepted + bonus) — the next
            // cycle's catch-up chunk absorbs the gap.
            let keep = r.tokens.len() - 1;
            r.cache.truncate(keep, pool);
            let d = r.draft.as_mut().expect("draft state");
            d.cache.truncate(keep, &mut se.pool);
            d.note_cycle(proposals[j].len(), acc);
            if !d.disabled && d.collapsed() {
                d.disabled = true;
                d.cache.release_all(&mut se.pool);
                se.counters.fallbacks += 1;
            }
            handled[i] = true;
        }
        rec.phase_ns[PH_SAMPLE] += t_accept.elapsed().as_nanos() as u64;
        Ok(handled)
    }
}

/// Where a shareable prefix lives.
enum DonorRef {
    Active(usize),
    Staged(usize),
}
