//! Quickstart: the whole ApiQ story in ~40 lines of public API.
//!
//!   1. pretrain a TinyLlama on the synthetic corpus (or reuse the cache)
//!   2. quantize it to 2 bits with RTN (naive) and ApiQ-bw (the paper)
//!   3. compare perplexity against the full-precision model
//!
//! Run:  make artifacts && cargo run --release --offline --example quickstart

use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};

fn main() -> repro::Result<()> {
    // Pretrain (cached under checkpoints/) + calibration batches.
    let env = Env::prepare("artifacts", "tiny", repro::pipeline::default_pretrain_steps("tiny"), 17)?;

    let eval_batches = 6;
    let fp = env.ppl_fp(eval_batches)?;
    println!("full-precision perplexity: {fp:.3}");

    let mut table = TableBuilder::new("Quickstart — 2-bit PTQ perplexity (tiny)")
        .header(&["method", "ppl", "quant time (s)"]);
    table.row(vec!["fp32".into(), TableBuilder::num(fp), "-".into()]);

    for method in ["rtn", "apiq-bw"] {
        let r = env.quantize(method, 2, DEFAULT_GROUP, DEFAULT_RANK)?;
        let ppl = env.ppl(&r, DEFAULT_RANK, DEFAULT_GROUP, eval_batches)?;
        println!("{method}: ppl {ppl:.3} ({:.1}s)", r.wall_secs);
        table.row(vec![
            method.into(),
            TableBuilder::num(ppl),
            format!("{:.1}", r.wall_secs),
        ]);
    }

    println!("{}", table.markdown());
    println!("expected shape: fp < apiq-bw << rtn (2-bit RTN collapses)");
    Ok(())
}
