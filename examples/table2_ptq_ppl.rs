//! Table 2 — ApiQ as post-training quantization (no finetuning):
//! perplexity of QLoRA / LoftQ / ApiQ-lw / ApiQ-bw at 4/3/2 bits on two
//! model sizes (the paper's 7B/13B axis -> our tiny/small).
//!
//! Expected shape (paper): ApiQ-bw best, ApiQ-lw second, gap widening at
//! lower bits; QLoRA collapses at 3- and 2-bit.
//!
//! Run:  cargo run --release --offline --example table2_ptq_ppl
//!       [--sizes tiny,small] [--bits 4,3,2] [--methods ...]

use repro::config::args::Args;
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let sizes = args.list_or("sizes", &["tiny"]);
    let bits_list = args.u32_list_or("bits", &[4, 3, 2])?;
    let methods = args.list_or("methods", &["qlora", "loftq", "apiq-lw", "apiq-bw"]);
    let eval_batches = args.usize_or("eval-batches", 6)?;

    let mut table = TableBuilder::new("Table 2 — PTQ perplexity (lower is better)")
        .header(&["method", "bits", "size", "ppl"]);

    for size in &sizes {
        let env = Env::prepare("artifacts", size, repro::pipeline::default_pretrain_steps(size), 17)?;
        let fp = env.ppl_fp(eval_batches)?;
        table.row(vec!["fp".into(), "16".into(), size.clone(), TableBuilder::num(fp)]);
        for &bits in &bits_list {
            for method in &methods {
                let r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
                let ppl = env.ppl(&r, DEFAULT_RANK, DEFAULT_GROUP, eval_batches)?;
                println!("[table2] {size} {method} {bits}-bit: ppl {ppl:.3}");
                table.row(vec![
                    method.clone(),
                    bits.to_string(),
                    size.clone(),
                    TableBuilder::num(ppl),
                ]);
            }
        }
    }
    println!("{}", table.markdown());
    Ok(())
}
