//! End-to-end validation driver (DESIGN.md §6, EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real workload:
//!   1. pretrain a TinyLlama on the Zipf-Markov corpus, logging the loss
//!      curve (L2 pretrain_step artifacts through the L3 driver);
//!   2. quantize at 2-bit with QLoRA / LoftQ / ApiQ-bw (baselines host-side
//!      in Rust, ApiQ through the L1-kerneled calibration artifacts);
//!   3. evaluate PTQ perplexity (Table 2 shape);
//!   4. LoRA-finetune each quantized model on the arithmetic task and
//!      report accuracy (Table 6 shape).
//!
//! Flags: --model tiny|small|base   (default tiny; base is the ~100M model
//!        — expect hours on a single-core CPU host)
//!        --pretrain-steps N --ft-steps N --methods a,b,c

use repro::config::args::Args;
use repro::data::tasks::ArithTask;
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::train::{FinetuneData, LoraPosition};

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("model", "tiny");
    let pretrain_steps = args.usize_or(
        "pretrain-steps",
        repro::pipeline::default_pretrain_steps(&size),
    )?;
    let ft_steps = args.usize_or("ft-steps", 80)?;
    let methods = args.list_or("methods", &["qlora", "loftq", "apiq-bw"]);
    let seed = args.u64_or("seed", 17)?;

    println!("=== E2E full run: model={size}, pretrain={pretrain_steps} steps ===");
    let t0 = std::time::Instant::now();
    let env = Env::prepare("artifacts", &size, pretrain_steps, seed)?;
    println!("[e2e] env ready at {:.1}s", t0.elapsed().as_secs_f64());

    let eval_batches = 6;
    let fp = env.ppl_fp(eval_batches)?;
    println!("[e2e] fp perplexity: {fp:.3}");

    let arith = ArithTask::add(env.cfg.vocab, seed ^ 0xA17);
    let mut table = TableBuilder::new(format!(
        "E2E — 2-bit quantize + finetune ({size}, r{DEFAULT_RANK}, g{DEFAULT_GROUP})"
    ))
    .header(&["method", "ptq ppl", "ft ppl", "arith acc %", "quant s", "ft s"]);
    table.row(vec![
        "fp (no quant)".into(),
        TableBuilder::num(fp),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);

    for method in &methods {
        println!("[e2e] --- {method} ---");
        let mut r = env.quantize(method, 2, DEFAULT_GROUP, DEFAULT_RANK)?;
        let ptq_ppl = env.ppl(&r, DEFAULT_RANK, DEFAULT_GROUP, eval_batches)?;
        println!("[e2e] {method}: PTQ ppl {ptq_ppl:.3} ({:.1}s quant)", r.wall_secs);

        let ft = env.finetune(
            &mut r,
            DEFAULT_RANK,
            DEFAULT_GROUP,
            &FinetuneData::Task(&arith),
            ft_steps,
            1e-3,
            LoraPosition::All,
        )?;
        let ft_ppl = env.ppl(&r, DEFAULT_RANK, DEFAULT_GROUP, eval_batches)?;
        let acc = env.task_accuracy(&r, DEFAULT_RANK, DEFAULT_GROUP, &arith, 8, false)?;
        println!(
            "[e2e] {method}: ft loss {:.3} -> {:.3}; arith acc {:.1}%",
            ft.losses.first().copied().unwrap_or(f32::NAN),
            ft.tail_mean(10),
            acc * 100.0
        );
        table.row(vec![
            method.clone(),
            TableBuilder::num(ptq_ppl),
            TableBuilder::num(ft_ppl),
            TableBuilder::pct(acc),
            format!("{:.1}", r.wall_secs),
            format!("{:.1}", ft.wall_secs),
        ]);
    }

    println!("{}", table.markdown());
    println!(
        "[e2e] total wall time {:.1}s — expected shape: ApiQ-bw best ppl/acc, \
         QLoRA collapses at 2-bit, LoftQ in between",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
