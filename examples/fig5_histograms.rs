//! Fig. 5 / A.2–A.5 — histograms of W, Q, A·Bᵀ, A and B for a 2-bit
//! quantized layer, LoftQ vs ApiQ.
//!
//! Paper observations to reproduce:
//!   * Q takes at most 2^b distinct levels per group scale;
//!   * ApiQ's A·Bᵀ concentrates in the center region where uniform
//!     quantization collapses many W values onto one level;
//!   * ApiQ's A/B distributions are much narrower than LoftQ's
//!     (measured here as the central 95% span).
//!
//! Run:  cargo run --release --offline --example fig5_histograms
//!       [--size tiny] [--layer blocks.3.wo]

use repro::config::args::Args;
use repro::metrics::Histogram;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::quant::{fakequant, QuantSpec};
use repro::tensor::Tensor;

fn describe(name: &str, t: &Tensor) -> (Histogram, String) {
    let h = Histogram::auto(t.data(), 41);
    let span = h.central_span(0.95);
    let line = format!(
        "{name:<10} n={:<8} span95={span:.4}  min..max [{:.4}, {:.4}]",
        t.len(),
        h.lo,
        h.hi
    );
    (h, line)
}

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;
    // the paper shows the output projection of a late block
    let layer = args.str_or("layer", &format!("blocks.{}.wo", env.cfg.n_layers - 1));
    let bits = args.u32_or("bits", 2)?;
    let spec = QuantSpec::new(bits, DEFAULT_GROUP);

    let w = env.params.require(&layer)?.clone();

    for method in ["loftq", "apiq-bw"] {
        println!("\n==== {method} ({layer}, {bits}-bit) ====");
        let r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
        let qp = r.qparams.view(&format!("{layer}."));
        let a = qp.require("lora_a")?;
        let b = qp.require("lora_b")?;
        let q = if r.eval_bits >= 16.0 {
            r.params.require(&layer)?.clone()
        } else {
            fakequant(r.params.require(&layer)?, qp.require("gamma")?, qp.require("beta")?, spec)?
        };
        let ab = a.matmul(&b.transpose()?)?;

        let (_, lw) = describe("W", &w);
        let (hq, lq) = describe("Q", &q);
        let (hab, lab) = describe("A·B^T", &ab);
        let (_, la) = describe("A", a);
        let (_, lb) = describe("B", b);
        println!("{lw}\n{lq}\n{lab}\n{la}\n{lb}");
        println!(
            "Q populated histogram bins: {} (2-bit grid per group -> few levels)",
            hq.populated_bins()
        );
        println!("\nA·B^T histogram (the paper's center-mass panel):");
        print!("{}", hab.render(48));
    }

    println!(
        "\nexpected shape: ApiQ's A/B span95 well below LoftQ's; ApiQ's A·B^T \
         mass concentrated near 0 (compensating the quantizer's dead zone)"
    );
    Ok(())
}
