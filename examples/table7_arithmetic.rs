//! Table 7 — multi-task arithmetic reasoning: finetune ONE quantized
//! model on a task mixture (Math10K analogue), evaluate on four held-out
//! suites (GSM8K*, SVAMP*, MAWPS*, AQuA*).
//!
//! Expected shape (paper): at 2-bit QLoRA collapses to noise, GPTQ-LoRA
//! partially recovers, LoftQ better, ApiQ-bw best average.
//!
//! Run:  cargo run --release --offline --example table7_arithmetic
//!       [--size tiny] [--bits 2] [--ft-steps 120]

use repro::config::args::Args;
use repro::data::tasks::{arithmetic_suite, Task};
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::train::{FinetuneData, LoraPosition};

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let bits_list = args.u32_list_or("bits", &[2])?;
    let ft_steps = args.usize_or("ft-steps", 120)?;
    let methods = args.list_or("methods", &["qlora", "gptq", "loftq", "apiq-bw"]);
    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;

    let (tasks, names) = arithmetic_suite(env.cfg.vocab, 1234);

    let mut header = vec!["method".to_string(), "bits".to_string()];
    header.extend(names.iter().cloned());
    header.push("avg".into());
    let mut table = TableBuilder::new(format!("Table 7 — multi-task arithmetic ({size})"))
        .header(&header.iter().map(String::as_str).collect::<Vec<_>>());

    for &bits in &bits_list {
        for method in &methods {
            let mut r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
            let mixture: Vec<&dyn Task> = tasks.iter().map(|t| t.as_ref()).collect();
            env.finetune(
                &mut r,
                DEFAULT_RANK,
                DEFAULT_GROUP,
                &FinetuneData::Mixture(mixture),
                ft_steps,
                1e-3,
                LoraPosition::All,
            )?;
            let mut accs = Vec::new();
            for (task, name) in tasks.iter().zip(&names) {
                let mc = name.starts_with("AQuA");
                let acc =
                    env.task_accuracy(&r, DEFAULT_RANK, DEFAULT_GROUP, task.as_ref(), 8, mc)?;
                println!("[table7] {method} {bits}-bit {name}: {:.1}%", acc * 100.0);
                accs.push(acc);
            }
            let avg = accs.iter().sum::<f64>() / accs.len() as f64;
            let mut row = vec![method.clone(), bits.to_string()];
            row.extend(accs.iter().map(|a| TableBuilder::pct(*a)));
            row.push(TableBuilder::pct(avg));
            table.row(row);
        }
    }
    println!("{}", table.markdown());
    Ok(())
}
