//! Fig. 1 — the headline cross-task comparison: one compact sweep over
//! tasks x bit-widths x methods, the union of Tables 5-8 at reduced
//! budget (this is the figure the paper opens with).
//!
//! Run:  cargo run --release --offline --example fig1_headline
//!       [--size tiny] [--bits 4,3,2] [--ft-steps 60]

use repro::config::args::Args;
use repro::data::tasks::{ArithTask, ClassifyTask, McTask};
use repro::data::ZipfMarkovCorpus;
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::train::{FinetuneData, LoraPosition};

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let bits_list = args.u32_list_or("bits", &[4, 3, 2])?;
    let ft_steps = args.usize_or("ft-steps", 60)?;
    let methods = args.list_or("methods", &["qlora", "loftq", "apiq-bw"]);
    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;

    let corpus = ZipfMarkovCorpus::new(env.cfg.vocab, 17);
    let glue = ClassifyTask::new(env.cfg.vocab, 3, 101);
    let gsm = ArithTask::add(env.cfg.vocab, 909);
    let cs = McTask::pattern(env.cfg.vocab, 1);

    let mut table = TableBuilder::new(format!("Fig. 1 — headline sweep ({size})")).header(&[
        "method", "bits", "WikiText* ppl", "GLUE* acc", "GSM8K* acc", "CS* acc",
    ]);

    for &bits in &bits_list {
        for method in &methods {
            // LM
            let mut r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
            env.finetune(&mut r, DEFAULT_RANK, DEFAULT_GROUP,
                         &FinetuneData::Corpus(&corpus), ft_steps, 1e-3, LoraPosition::All)?;
            let ppl = env.ppl(&r, DEFAULT_RANK, DEFAULT_GROUP, 4)?;
            // GLUE*
            let mut r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
            env.finetune(&mut r, DEFAULT_RANK, DEFAULT_GROUP,
                         &FinetuneData::Task(&glue), ft_steps, 1e-3, LoraPosition::All)?;
            let acc_glue = env.task_accuracy(&r, DEFAULT_RANK, DEFAULT_GROUP, &glue, 6, true)?;
            // GSM8K*
            let mut r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
            env.finetune(&mut r, DEFAULT_RANK, DEFAULT_GROUP,
                         &FinetuneData::Task(&gsm), ft_steps, 1e-3, LoraPosition::All)?;
            let acc_gsm = env.task_accuracy(&r, DEFAULT_RANK, DEFAULT_GROUP, &gsm, 6, false)?;
            // commonsense*
            let mut r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
            env.finetune(&mut r, DEFAULT_RANK, DEFAULT_GROUP,
                         &FinetuneData::Task(&cs), ft_steps, 1e-3, LoraPosition::All)?;
            let acc_cs = env.task_accuracy(&r, DEFAULT_RANK, DEFAULT_GROUP, &cs, 6, true)?;

            println!(
                "[fig1] {method} {bits}-bit: ppl {ppl:.2} glue {:.1} gsm {:.1} cs {:.1}",
                acc_glue * 100.0, acc_gsm * 100.0, acc_cs * 100.0
            );
            table.row(vec![
                method.clone(),
                bits.to_string(),
                TableBuilder::num(ppl),
                TableBuilder::pct(acc_glue),
                TableBuilder::pct(acc_gsm),
                TableBuilder::pct(acc_cs),
            ]);
        }
    }
    println!("{}", table.markdown());
    Ok(())
}
