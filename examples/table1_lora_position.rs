//! Table 1 — the trainable-LoRA-position ablation.
//!
//! Finetune only the adapters in {All, FFN, Attn} positions after 2-bit
//! quantization with QLoRA / LoftQ / ApiQ-lw init.  The paper's finding:
//! QLoRA and LoftQ degrade badly when only a subset is trained (the
//! untouched layers keep their quantization error), while ApiQ has the
//! smallest gap across positions — its calibration already fixed every
//! layer.
//!
//! Run:  cargo run --release --offline --example table1_lora_position
//!       [--size tiny] [--ft-steps 80]

use repro::config::args::Args;
use repro::data::ZipfMarkovCorpus;
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::train::{FinetuneData, LoraPosition};

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let ft_steps = args.usize_or("ft-steps", 80)?;
    let methods = args.list_or("methods", &["qlora", "loftq", "apiq-lw"]);
    let bits = args.u32_or("bits", 2)?;

    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;
    let corpus = ZipfMarkovCorpus::new(env.cfg.vocab, 17);
    let fp = env.ppl_fp(6)?;
    println!("[table1] fp ppl {fp:.3}");

    let mut table = TableBuilder::new(format!(
        "Table 1 — LoRA position ablation ({size}, {bits}-bit, WikiText* ppl)"
    ))
    .header(&["method", "position", "ft ppl", "gap vs All"]);

    for method in &methods {
        let mut best_all = f64::NAN;
        for (pos, pos_name) in [
            (LoraPosition::All, "All"),
            (LoraPosition::FfnOnly, "FFN"),
            (LoraPosition::AttnOnly, "Attn"),
        ] {
            let mut r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
            env.finetune(
                &mut r,
                DEFAULT_RANK,
                DEFAULT_GROUP,
                &FinetuneData::Corpus(&corpus),
                ft_steps,
                1e-3,
                pos,
            )?;
            let ppl = env.ppl(&r, DEFAULT_RANK, DEFAULT_GROUP, 6)?;
            if pos == LoraPosition::All {
                best_all = ppl;
            }
            let gap = ppl - best_all;
            println!("[table1] {method} {pos_name}: ppl {ppl:.3} (gap {gap:+.3})");
            table.row(vec![
                method.clone(),
                pos_name.into(),
                TableBuilder::num(ppl),
                format!("{gap:+.3}"),
            ]);
        }
    }
    println!("{}", table.markdown());
    println!("expected shape: ApiQ has the smallest All-vs-subset gap");
    Ok(())
}
