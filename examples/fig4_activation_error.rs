//! Fig. 4 — average per-token activation error per transformer block,
//! ‖X·W − X^q·(Q + A·Bᵀ)‖_F / n_tokens, measured on calibration data.
//!
//! The paper's central diagnostic: QLoRA's error explodes through depth,
//! LoftQ grows more slowly, ApiQ stays nearly flat (each block re-anchors
//! the quantized stream to the full-precision one).
//!
//! Run:  cargo run --release --offline --example fig4_activation_error
//!       [--size tiny] [--bits 2]

use repro::calib::CalibStreams;
use repro::config::args::Args;
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK, DEFAULT_SCALE};
use repro::quantizers::QuantResult;

/// Per-block divergence of the q-stream from the fp-stream under a
/// quantizer's parameters (per-token Frobenius norm of block outputs).
fn block_divergence(env: &Env, r: &QuantResult, bits: f32) -> repro::Result<Vec<f32>> {
    let mut streams = CalibStreams::init(&env.runtime, env.cfg, &env.params, &env.calib)?;
    let n_tok = (env.cfg.calib_batch * env.cfg.seq_len) as f32;
    let mut out = Vec::new();
    for b in 0..env.cfg.n_layers {
        let prefix = format!("blocks.{b}.");
        // quantized stream: the method's (possibly weight-overridden)
        // params + adapters; fp stream: the ORIGINAL pretrained weights
        let bp_q = r.params.view(&prefix);
        let bp_fp = env.params.view(&prefix);
        let bqp = r.qparams.view(&prefix);
        streams.advance_q(&env.runtime, &bp_q, &bqp, DEFAULT_RANK, DEFAULT_GROUP, bits, DEFAULT_SCALE)?;
        streams.advance_fp(&env.runtime, &bp_fp)?;
        let mut err = 0.0f32;
        for i in 0..streams.n_batches() {
            err += streams.x_fp[i].sub(&streams.x_q[i])?.fro_norm() / n_tok;
        }
        out.push(err / streams.n_batches() as f32);
    }
    Ok(out)
}

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let bits = args.u32_or("bits", 2)?;
    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;

    let methods = args.list_or("methods", &["qlora", "loftq", "apiq-lw", "apiq-bw"]);
    let mut rows: Vec<(String, Vec<f32>)> = Vec::new();
    for method in &methods {
        println!("[fig4] quantizing {method} ...");
        let r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
        let div = block_divergence(&env, &r, r.eval_bits)?;
        println!("[fig4] {method}: {div:?}");
        rows.push((method.clone(), div));
    }

    let mut header = vec!["method".to_string()];
    header.extend((0..env.cfg.n_layers).map(|b| format!("block {b}")));
    let mut table = TableBuilder::new(format!(
        "Fig. 4 — per-token activation error after each block ({size}, {bits}-bit)"
    ))
    .header(&header.iter().map(String::as_str).collect::<Vec<_>>());
    for (m, div) in &rows {
        let mut row = vec![m.clone()];
        row.extend(div.iter().map(|e| format!("{e:.4}")));
        table.row(row);
    }
    println!("{}", table.markdown());
    println!(
        "expected shape: monotone growth for qlora/loftq (error accumulation, \
         §3.2); ApiQ flat and lowest (§4.1)"
    );
    Ok(())
}
