//! Table 3 — ApiQ-bw vs standard PTQ methods (RTN, GPTQ, AWQ,
//! OmniQuant) at group sizes 64 and 128.
//!
//! Expected shape (paper): ApiQ-bw smallest perplexity at every bit
//! level, advantage growing at 2-bit; AWQ collapses at 2-bit; OmniQuant
//! (= ApiQ minus LoRA) second-best.
//!
//! Run:  cargo run --release --offline --example table3_ptq_baselines
//!       [--size tiny] [--bits 4,3,2] [--groups 64,128]

use repro::config::args::Args;
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_RANK};

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let bits_list = args.u32_list_or("bits", &[4, 3, 2])?;
    let groups: Vec<usize> = args
        .list_or("groups", &["64", "128"])
        .iter()
        .map(|s| s.parse().unwrap_or(64))
        .collect();
    let methods = args.list_or("methods", &["rtn", "gptq", "awq", "omniquant", "apiq-bw"]);
    let eval_batches = args.usize_or("eval-batches", 6)?;

    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;
    let fp = env.ppl_fp(eval_batches)?;

    let mut table = TableBuilder::new(format!("Table 3 — PTQ baselines ({size})"))
        .header(&["method", "bits", "group", "ppl"]);
    table.row(vec!["fp".into(), "16".into(), "-".into(), TableBuilder::num(fp)]);

    for &bits in &bits_list {
        for &group in &groups {
            // group-128 artifacts exist for the learned methods only at
            // the sizes emitted by aot.py; host-side methods work anywhere
            for method in &methods {
                let needs_g_artifact = matches!(method.as_str(), "omniquant" | "apiq-bw");
                if needs_g_artifact && group != 64 {
                    let name =
                        format!("bw_calib_{size}_r{DEFAULT_RANK}_g{group}");
                    if !env.runtime.has_artifact(&name) {
                        println!("[table3] skip {method} g{group} (artifact {name} not built)");
                        continue;
                    }
                }
                let r = env.quantize(method, bits, group, DEFAULT_RANK)?;
                let ppl = env.ppl(&r, DEFAULT_RANK, group, eval_batches)?;
                println!("[table3] {method} {bits}-bit g{group}: ppl {ppl:.3}");
                table.row(vec![
                    method.clone(),
                    bits.to_string(),
                    group.to_string(),
                    TableBuilder::num(ppl),
                ]);
            }
        }
    }
    println!("{}", table.markdown());
    Ok(())
}
