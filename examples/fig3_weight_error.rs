//! Fig. 3 / Fig. A.1 — per-layer weight error ‖W − (Q + A·Bᵀ)‖_F.
//!
//! Left panel analogue:  e = ‖δW‖(QLoRA) − ‖δW‖(LoftQ)   (LoftQ wins)
//! Middle panel analogue: e = ‖δW‖(LoftQ) − ‖δW‖(ApiQ)   (ApiQ mostly wins
//! despite optimizing activations, the paper's "dual effectiveness")
//!
//! Run:  cargo run --release --offline --example fig3_weight_error
//!       [--size tiny] [--bits 2]

use repro::config::args::Args;
use repro::metrics::{effective_weight, weight_error, TableBuilder};
use repro::model::LINEAR_NAMES;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK, DEFAULT_SCALE};
use repro::quant::{fakequant, nf_fakequant, QuantSpec};

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let bits = args.u32_or("bits", 2)?;
    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;
    let spec = QuantSpec::new(bits, DEFAULT_GROUP);

    println!("[fig3] quantizing with qlora/loftq/apiq-bw ...");
    let r_qlora = env.quantize("qlora", bits, DEFAULT_GROUP, DEFAULT_RANK)?;
    let r_loftq = env.quantize("loftq", bits, DEFAULT_GROUP, DEFAULT_RANK)?;
    let r_apiq = env.quantize("apiq-bw", bits, DEFAULT_GROUP, DEFAULT_RANK)?;

    let mut table = TableBuilder::new(format!(
        "Fig. 3 — weight error per layer ({size}, {bits}-bit): relative improvements"
    ))
    .header(&[
        "layer",
        "|dW| qlora",
        "|dW| loftq",
        "|dW| apiq",
        "qlora-loftq",
        "loftq-apiq",
    ]);

    let (mut wins_loftq, mut wins_apiq, mut total) = (0usize, 0usize, 0usize);
    for b in 0..env.cfg.n_layers {
        for lin in LINEAR_NAMES {
            let key = env.cfg.weight_key(b, lin);
            let w = env.params.require(&key)?;

            // QLoRA: NF-quantized weights, B = 0 -> Q_eff = nf(W)
            let e_qlora = weight_error(w, &nf_fakequant(w, bits, DEFAULT_GROUP)?)?;

            // LoftQ: overridden Q + its A,B
            let q_l = r_loftq.params.require(&key)?;
            let qp_l = r_loftq.qparams.view(&env.cfg.qparam_prefix(b, lin));
            let e_loftq = weight_error(w, &effective_weight(q_l, &qp_l, DEFAULT_SCALE)?)?;

            // ApiQ: in-graph quantizer -> host fakequant with learned gamma/beta
            let qp_a = r_apiq.qparams.view(&env.cfg.qparam_prefix(b, lin));
            let q_a = fakequant(
                r_apiq.params.require(&key)?,
                qp_a.require("gamma")?,
                qp_a.require("beta")?,
                spec,
            )?;
            let e_apiq = weight_error(w, &effective_weight(&q_a, &qp_a, DEFAULT_SCALE)?)?;

            total += 1;
            if e_loftq < e_qlora {
                wins_loftq += 1;
            }
            if e_apiq < e_loftq {
                wins_apiq += 1;
            }
            table.row(vec![
                key,
                format!("{e_qlora:.4}"),
                format!("{e_loftq:.4}"),
                format!("{e_apiq:.4}"),
                format!("{:+.4}", e_qlora - e_loftq),
                format!("{:+.4}", e_loftq - e_apiq),
            ]);
        }
    }
    println!("{}", table.markdown());
    println!(
        "[fig3] LoftQ beats QLoRA on {wins_loftq}/{total} layers; \
         ApiQ beats LoftQ on {wins_apiq}/{total} layers \
         (paper: positive on most layers in both panels)"
    );
    Ok(())
}
