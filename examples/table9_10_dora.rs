//! Tables 9 & 10 — ApiQ-bw with DoRA vs QDoRA (§6: "ApiQ-bw for other
//! PEFT").  QDoRA = naive RTN quantization + default-init DoRA adapters;
//! ApiQ-bw+DoRA = the same adapter *initialized by block-wise ApiQ
//! calibration* (LoftQ cannot do this — SVD has no answer to DoRA's
//! multiplicative magnitude, §3.3).
//!
//! Expected shape (paper): ApiQ-bw+DoRA >> QDoRA at 2-bit on both the
//! commonsense (T9) and arithmetic (T10) suites.
//!
//! Run:  cargo run --release --offline --example table9_10_dora
//!       [--size tiny] [--ft-steps 120]

use repro::config::args::Args;
use repro::data::tasks::{arithmetic_suite, commonsense_suite, Task};
use repro::metrics::TableBuilder;
use repro::model::LINEAR_NAMES;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::quantizers::{QuantResult, Quantizer};
use repro::tensor::Tensor;
use repro::train::{FinetuneData, LoraPosition};

/// QDoRA baseline: RTN-style open-clip quantization at native bits with
/// default DoRA init (mag = column norms of W, B = 0).
fn qdora(env: &Env, bits: u32) -> repro::Result<QuantResult> {
    let ctx = env.ctx(repro::quant::QuantSpec::new(bits, DEFAULT_GROUP), DEFAULT_RANK);
    let mut qparams = env.cfg.init_qparams(ctx.spec, DEFAULT_RANK, true, 99);
    // open clip (plain RTN grid) + mag = ||W||_col
    for key in qparams.keys().cloned().collect::<Vec<_>>() {
        if key.ends_with(".gamma") || key.ends_with(".beta") {
            for v in qparams.get_mut(&key).unwrap().data_mut() {
                *v = 30.0;
            }
        }
    }
    for b in 0..env.cfg.n_layers {
        for lin in LINEAR_NAMES {
            let w = env.params.require(&env.cfg.weight_key(b, lin))?;
            let (d_in, d_out) = env.cfg.linear_shape(lin);
            let mut mag = Tensor::zeros(&[d_out]);
            for c in 0..d_out {
                let mut s = 0.0f32;
                for r in 0..d_in {
                    s += w.at2(r, c) * w.at2(r, c);
                }
                mag.data_mut()[c] = s.sqrt();
            }
            qparams.insert(format!("{}mag", env.cfg.qparam_prefix(b, lin)), mag);
        }
    }
    Ok(QuantResult {
        method: "qdora".into(),
        params: env.params.clone(),
        qparams,
        eval_bits: bits as f32,
        wall_secs: 0.0,
    })
}

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let bits = args.u32_or("bits", 2)?;
    let ft_steps = args.usize_or("ft-steps", 120)?;
    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;

    let cs_tasks = commonsense_suite(env.cfg.vocab);
    let (ar_tasks, ar_names) = arithmetic_suite(env.cfg.vocab, 1234);

    let mut t9 = TableBuilder::new(format!("Table 9 — DoRA commonsense ({size}, {bits}-bit)"))
        .header(&["method", "avg acc %"]);
    let mut t10 = TableBuilder::new(format!("Table 10 — DoRA arithmetic ({size}, {bits}-bit)"))
        .header(&["method", "GSM8K*", "SVAMP*", "MAWPS*", "AQuA*", "avg"]);

    for method in ["qdora", "apiq-bw-dora"] {
        let make = || -> repro::Result<QuantResult> {
            if method == "qdora" {
                qdora(&env, bits)
            } else {
                let ctx = env.ctx(repro::quant::QuantSpec::new(bits, DEFAULT_GROUP), DEFAULT_RANK);
                repro::quantizers::ApiQ::bw_dora().run(&ctx)
            }
        };

        // Table 9: commonsense mixture
        let mut r = make()?;
        let mixture: Vec<&dyn Task> = cs_tasks.iter().map(|t| t as &dyn Task).collect();
        env.finetune(&mut r, DEFAULT_RANK, DEFAULT_GROUP, &FinetuneData::Mixture(mixture),
                     ft_steps, 1e-3, LoraPosition::All)?;
        let mut accs = Vec::new();
        for task in &cs_tasks {
            accs.push(env.task_accuracy(&r, DEFAULT_RANK, DEFAULT_GROUP, task, 6, true)?);
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        println!("[table9] {method}: avg {:.1}%", avg * 100.0);
        t9.row(vec![method.into(), TableBuilder::pct(avg)]);

        // Table 10: arithmetic mixture
        let mut r = make()?;
        let mixture: Vec<&dyn Task> = ar_tasks.iter().map(|t| t.as_ref()).collect();
        env.finetune(&mut r, DEFAULT_RANK, DEFAULT_GROUP, &FinetuneData::Mixture(mixture),
                     ft_steps, 1e-3, LoraPosition::All)?;
        let mut row = vec![method.to_string()];
        let mut accs = Vec::new();
        for (task, name) in ar_tasks.iter().zip(&ar_names) {
            let mc = name.starts_with("AQuA");
            let acc = env.task_accuracy(&r, DEFAULT_RANK, DEFAULT_GROUP, task.as_ref(), 8, mc)?;
            println!("[table10] {method} {name}: {:.1}%", acc * 100.0);
            accs.push(acc);
            row.push(TableBuilder::pct(acc));
        }
        row.push(TableBuilder::pct(accs.iter().sum::<f64>() / accs.len() as f64));
        t10.row(row);
    }

    println!("{}", t9.markdown());
    println!("{}", t10.markdown());
    println!("expected shape: apiq-bw-dora >> qdora on both tables");
    Ok(())
}
