//! Table 6 — finetuning results: WikiText* perplexity and GSM8K*
//! accuracy per method and bit-width.
//!
//! Expected shape (paper): ApiQ-bw best at every bit level, ApiQ-lw
//! second; differences grow at 2-bit where QLoRA returns N.A.-grade
//! numbers.
//!
//! Run:  cargo run --release --offline --example table6_lm_gsm
//!       [--size tiny] [--bits 4,3,2] [--ft-steps 80]

use repro::config::args::Args;
use repro::data::tasks::ArithTask;
use repro::data::ZipfMarkovCorpus;
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::train::{FinetuneData, LoraPosition};

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let bits_list = args.u32_list_or("bits", &[4, 3, 2])?;
    let ft_steps = args.usize_or("ft-steps", 80)?;
    let methods = args.list_or("methods", &["qlora", "loftq", "apiq-lw", "apiq-bw"]);
    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;
    let corpus = ZipfMarkovCorpus::new(env.cfg.vocab, 17);
    let gsm = ArithTask::add(env.cfg.vocab, 909);

    let mut table = TableBuilder::new(format!("Table 6 — finetune ppl/acc ({size})"))
        .header(&["method", "bits", "WikiText* (ppl)", "GSM8K* (acc %)"]);

    for &bits in &bits_list {
        for method in &methods {
            // WikiText*: finetune on the corpus, report held-out ppl
            let mut r1 = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
            env.finetune(
                &mut r1, DEFAULT_RANK, DEFAULT_GROUP,
                &FinetuneData::Corpus(&corpus), ft_steps, 1e-3, LoraPosition::All,
            )?;
            let ppl = env.ppl(&r1, DEFAULT_RANK, DEFAULT_GROUP, 6)?;

            // GSM8K*: separate finetune on arithmetic
            let mut r2 = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
            env.finetune(
                &mut r2, DEFAULT_RANK, DEFAULT_GROUP,
                &FinetuneData::Task(&gsm), ft_steps, 1e-3, LoraPosition::All,
            )?;
            let acc = env.task_accuracy(&r2, DEFAULT_RANK, DEFAULT_GROUP, &gsm, 8, false)?;

            println!("[table6] {method} {bits}-bit: ppl {ppl:.3}, acc {:.1}%", acc * 100.0);
            table.row(vec![
                method.clone(),
                bits.to_string(),
                TableBuilder::num(ppl),
                TableBuilder::pct(acc),
            ]);
        }
    }
    println!("{}", table.markdown());
    Ok(())
}
