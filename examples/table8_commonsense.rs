//! Table 8 — commonsense reasoning: one quantized model finetuned on the
//! combined training set of eight pattern-completion suites (the BoolQ /
//! PIQA / ... / OBQA analogues), MC accuracy per suite.
//!
//! Expected shape (paper): 2-bit GPTQ-LoRA near chance, LoftQ partial,
//! ApiQ-bw >10 points above LoftQ on average.
//!
//! Run:  cargo run --release --offline --example table8_commonsense
//!       [--size tiny] [--bits 2] [--ft-steps 120]

use repro::config::args::Args;
use repro::data::tasks::{commonsense_suite, Task};
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::train::{FinetuneData, LoraPosition};

const SUITE_NAMES: [&str; 8] =
    ["BoolQ*", "PIQA*", "SIQA*", "HellaS*", "WinoG*", "ARC-e*", "ARC-c*", "OBQA*"];

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let bits_list = args.u32_list_or("bits", &[2])?;
    let ft_steps = args.usize_or("ft-steps", 120)?;
    let methods = args.list_or("methods", &["gptq", "loftq", "apiq-bw"]);
    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;

    let tasks = commonsense_suite(env.cfg.vocab);

    let mut header = vec!["method".to_string(), "bits".to_string()];
    header.extend(SUITE_NAMES.iter().map(|s| s.to_string()));
    header.push("avg".into());
    let mut table = TableBuilder::new(format!("Table 8 — commonsense MC accuracy ({size})"))
        .header(&header.iter().map(String::as_str).collect::<Vec<_>>());

    for &bits in &bits_list {
        for method in &methods {
            let mut r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
            let mixture: Vec<&dyn Task> = tasks.iter().map(|t| t as &dyn Task).collect();
            env.finetune(
                &mut r,
                DEFAULT_RANK,
                DEFAULT_GROUP,
                &FinetuneData::Mixture(mixture),
                ft_steps,
                1e-3,
                LoraPosition::All,
            )?;
            let mut accs = Vec::new();
            for (task, name) in tasks.iter().zip(SUITE_NAMES) {
                let acc = env.task_accuracy(&r, DEFAULT_RANK, DEFAULT_GROUP, task, 6, true)?;
                println!("[table8] {method} {bits}-bit {name}: {:.1}%", acc * 100.0);
                accs.push(acc);
            }
            let avg = accs.iter().sum::<f64>() / accs.len() as f64;
            let mut row = vec![method.clone(), bits.to_string()];
            row.extend(accs.iter().map(|a| TableBuilder::pct(*a)));
            row.push(TableBuilder::pct(avg));
            table.row(row);
        }
    }
    println!("{}", table.markdown());
    Ok(())
}
