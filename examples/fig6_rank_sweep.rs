//! Fig. 6 — WikiText* perplexity vs LoRA rank at 2-bit.
//!
//! Expected shape (paper): ApiQ nearly flat across ranks (rank-
//! insensitive), LoftQ improves with rank but stays above ApiQ, QLoRA
//! far above both at every rank.
//!
//! Run:  cargo run --release --offline --example fig6_rank_sweep
//!       [--ranks 2,8,16,64] [--ft-steps 60]
//!
//! (tiny only — the rank-swept artifacts are emitted for tiny.)

use repro::config::args::Args;
use repro::data::ZipfMarkovCorpus;
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP};
use repro::train::{FinetuneData, LoraPosition};

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let ranks: Vec<usize> = args
        .list_or("ranks", &["2", "8", "16", "64"])
        .iter()
        .map(|s| s.parse().unwrap_or(16))
        .collect();
    let ft_steps = args.usize_or("ft-steps", 60)?;
    let methods = args.list_or("methods", &["qlora", "loftq", "apiq-bw"]);
    let bits = args.u32_or("bits", 2)?;
    let env = Env::prepare("artifacts", "tiny", repro::pipeline::default_pretrain_steps("tiny"), 17)?;
    let corpus = ZipfMarkovCorpus::new(env.cfg.vocab, 17);

    let mut header = vec!["method".to_string()];
    header.extend(ranks.iter().map(|r| format!("r={r}")));
    let mut table = TableBuilder::new(format!("Fig. 6 — ppl vs LoRA rank (tiny, {bits}-bit)"))
        .header(&header.iter().map(String::as_str).collect::<Vec<_>>());

    for method in &methods {
        let mut row = vec![method.clone()];
        for &rank in &ranks {
            let name = format!("bw_calib_tiny_r{rank}_g{DEFAULT_GROUP}");
            if !env.runtime.has_artifact(&name) {
                println!("[fig6] skip r={rank} ({name} not built)");
                row.push("-".into());
                continue;
            }
            let mut r = env.quantize(method, bits, DEFAULT_GROUP, rank)?;
            env.finetune(
                &mut r, rank, DEFAULT_GROUP,
                &FinetuneData::Corpus(&corpus), ft_steps, 1e-3, LoraPosition::All,
            )?;
            let ppl = env.ppl(&r, rank, DEFAULT_GROUP, 6)?;
            println!("[fig6] {method} r={rank}: ppl {ppl:.3}");
            row.push(TableBuilder::num(ppl));
        }
        table.row(row);
    }
    println!("{}", table.markdown());
    println!("expected shape: ApiQ flat across ranks; others rank-hungry");
    Ok(())
}
