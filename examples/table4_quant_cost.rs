//! Table 4 — quantization duration and peak memory.
//!
//! Duration is measured (wall clock of each quantizer on this host);
//! peak memory combines the analytic model (paper-shape cross-check on
//! Llama-2-7B dims) with the measured RSS of this process per method.
//!
//! Expected shape (paper): GPTQ fastest/leanest; ApiQ-lw slow but lean;
//! ApiQ-bw ~3-4x faster than ApiQ-lw at higher memory; LoftQ most
//! memory-hungry (SVD).
//!
//! Run:  cargo run --release --offline --example table4_quant_cost

use repro::config::args::Args;
use repro::metrics::memory::{ArchShape, MemoryModel};
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::quant::QuantSpec;

fn rss_gb() -> f64 {
    // VmHWM from /proc/self/status (peak resident set), in GB.
    if let Ok(s) = std::fs::read_to_string("/proc/self/status") {
        for line in s.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: f64 = rest.trim().trim_end_matches(" kB").trim().parse().unwrap_or(0.0);
                return kb / 1e6;
            }
        }
    }
    f64::NAN
}

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let bits = args.u32_or("bits", 2)?;
    let methods = args.list_or("methods", &["gptq", "loftq", "omniquant", "apiq-lw", "apiq-bw"]);
    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;

    let mut table = TableBuilder::new(format!("Table 4 — quantization cost ({size}, {bits}-bit)"))
        .header(&[
            "method",
            "duration (s)",
            "RSS high-water (GB)",
            "model-peak @7B dims (GB)",
        ]);

    let model = MemoryModel::new(ArchShape::llama2_7b());
    let spec = QuantSpec::new(bits, DEFAULT_GROUP);
    let calib_tokens = 128 * 2048u64; // the paper's 128 x 2048-token setup

    for method in &methods {
        let r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
        let predicted = model.quantization_peak(method, spec, 64, calib_tokens) as f64 / 1e9;
        println!("[table4] {method}: {:.1}s (model-peak {predicted:.1} GB @7B)", r.wall_secs);
        table.row(vec![
            method.clone(),
            format!("{:.1}", r.wall_secs),
            format!("{:.2}", rss_gb()),
            format!("{predicted:.1}"),
        ]);
    }
    println!("{}", table.markdown());
    println!(
        "expected shape: duration gptq < apiq-bw ~ omniquant < apiq-lw; \
         model-peak loftq > apiq-bw > apiq-lw ~ gptq (Table 4)"
    );
    Ok(())
}
