//! Table 5 — natural language understanding (GLUE analogue).
//!
//! The paper finetunes encoder models on GLUE; our stand-in is k-way
//! sequence classification over Markov "styles" (MNLI-like 3-way and
//! SST-2-like 2-way), finetuned generatively with the label-token mask.
//!
//! Expected shape: at 2-bit, QLoRA far below LoftQ/ApiQ; ApiQ best
//! average.
//!
//! Run:  cargo run --release --offline --example table5_glue
//!       [--size tiny] [--bits 2] [--ft-steps 80]

use repro::config::args::Args;
use repro::data::tasks::ClassifyTask;
use repro::metrics::TableBuilder;
use repro::pipeline::{Env, DEFAULT_GROUP, DEFAULT_RANK};
use repro::train::{FinetuneData, LoraPosition};

fn main() -> repro::Result<()> {
    let args = Args::parse_env()?;
    let size = args.str_or("size", "tiny");
    let bits = args.u32_or("bits", 2)?;
    let ft_steps = args.usize_or("ft-steps", 80)?;
    let methods = args.list_or("methods", &["qlora", "loftq", "apiq-lw", "apiq-bw"]);
    let env = Env::prepare("artifacts", &size, repro::pipeline::default_pretrain_steps(&size), 17)?;

    // MNLI* (3-way), SST-2* (2-way), RTE* (2-way, different seed)
    let suites = [
        ("MNLI*", ClassifyTask::new(env.cfg.vocab, 3, 101)),
        ("SST-2*", ClassifyTask::new(env.cfg.vocab, 2, 202)),
        ("RTE*", ClassifyTask::new(env.cfg.vocab, 2, 303)),
    ];

    let mut header = vec!["method".to_string(), "bits".to_string()];
    header.extend(suites.iter().map(|(n, _)| n.to_string()));
    header.push("avg".into());
    let mut table = TableBuilder::new(format!("Table 5 — GLUE* accuracy ({size})"))
        .header(&header.iter().map(String::as_str).collect::<Vec<_>>());

    for method in &methods {
        let mut accs = Vec::new();
        for (name, task) in &suites {
            let mut r = env.quantize(method, bits, DEFAULT_GROUP, DEFAULT_RANK)?;
            env.finetune(
                &mut r,
                DEFAULT_RANK,
                DEFAULT_GROUP,
                &FinetuneData::Task(task),
                ft_steps,
                1e-3,
                LoraPosition::All,
            )?;
            let acc = env.task_accuracy(&r, DEFAULT_RANK, DEFAULT_GROUP, task, 8, true)?;
            println!("[table5] {method} {name}: {:.1}%", acc * 100.0);
            accs.push(acc);
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        let mut row = vec![method.clone(), bits.to_string()];
        row.extend(accs.iter().map(|a| TableBuilder::pct(*a)));
        row.push(TableBuilder::pct(avg));
        table.row(row);
    }
    println!("{}", table.markdown());
    Ok(())
}
