"""AOT lowering: every L2 step -> artifacts/<name>.hlo.txt + .manifest.

Interchange format is HLO *text* (NOT serialized HloModuleProto): jax>=0.5
emits protos with 64-bit instruction ids which the runtime's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Each artifact gets a sidecar manifest the Rust runtime parses to bind
buffers by name:

    arg <flat/key> <f32|i32> <ndim> <dim0> <dim1> ...
    ret <flat/key> <f32|i32> <ndim> <dim0> ...

Ordering is the jax pytree flattening order (dicts by sorted key), which is
exactly the parameter/tuple-element order of the lowered XLA computation.
Lowering uses keep_unused=True so no argument is DCE'd out of the
signature; an assertion cross-checks the program shape.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import steps

DEFAULT_RANK = 16
DEFAULT_GROUP = 64
# Extra LoRA ranks for the Fig. 6 rank sweep (tiny model only).
FIG6_RANKS = (2, 8, 64)
# Extra quantization group for Table 3 (group-size ablation).
TABLE3_GROUP = 128


def flatten_with_names(tree) -> list[tuple[str, jax.ShapeDtypeStruct]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p) for p in path
        )
        out.append((name, leaf))
    return out


def dtype_tag(dt) -> str:
    if dt == jnp.float32:
        return "f32"
    if dt == jnp.int32:
        return "i32"
    raise ValueError(f"unsupported artifact dtype {dt}")


def lower_to_hlo_text(fn, arg_specs) -> tuple[str, int]:
    lowered = jax.jit(fn, keep_unused=True).lower(arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    n_params = len(comp.program_shape().parameter_shapes())
    return comp.as_hlo_text(), n_params


def emit(name: str, builder, out_dir: str, force: bool, src_mtime: float) -> None:
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    man_path = os.path.join(out_dir, f"{name}.manifest")
    if (
        not force
        and os.path.exists(hlo_path)
        and os.path.exists(man_path)
        and os.path.getmtime(hlo_path) >= src_mtime
    ):
        print(f"  [skip] {name}")
        return

    t0 = time.time()
    fn, arg_specs = builder()
    in_flat = flatten_with_names(arg_specs)
    out_specs = jax.eval_shape(fn, arg_specs)
    out_flat = flatten_with_names(out_specs)

    text, n_params = lower_to_hlo_text(fn, arg_specs)
    assert n_params == len(in_flat), (
        f"{name}: lowered computation has {n_params} params, manifest has "
        f"{len(in_flat)} (an argument was DCE'd despite keep_unused?)"
    )

    with open(hlo_path, "w") as f:
        f.write(text)
    with open(man_path, "w") as f:
        for kind, flat in (("arg", in_flat), ("ret", out_flat)):
            for key, spec in flat:
                dims = " ".join(str(d) for d in spec.shape)
                f.write(f"{kind} {key} {dtype_tag(spec.dtype)} {len(spec.shape)} {dims}".rstrip() + "\n")
    print(f"  [ok]   {name}  ({len(text)/1e6:.2f} MB HLO, {len(in_flat)} args, {time.time()-t0:.1f}s)")


def artifact_plan(sizes: list[str], rank: int, group: int) -> list[tuple[str, object]]:
    """(name, builder-thunk) for every artifact in the standard set."""
    plan: list[tuple[str, object]] = []
    fq_shapes_done: set[tuple[int, int, int]] = set()

    for s in sizes:
        cfg = M.SIZES[s]
        r, g = rank, group
        plan.append((f"pretrain_step_{s}", lambda c=cfg: steps.build_pretrain_step(c)))
        plan.append((f"logits_fp_{s}", lambda c=cfg: steps.build_logits_fp(c)))
        plan.append((f"embed_fwd_{s}", lambda c=cfg: steps.build_embed_fwd(c)))
        plan.append((f"block_inputs_fp_{s}", lambda c=cfg: steps.build_block_inputs_fp(c)))

        def per_rg(s=s, cfg=cfg, r=r, g=g, tag=""):
            items = [
                (f"logits_q_{s}_r{r}_g{g}{tag}",
                 lambda: steps.build_logits_q(cfg, r, g)),
                (f"finetune_step_{s}_r{r}_g{g}{tag}",
                 lambda: steps.build_finetune_step(cfg, r, g)),
                (f"block_inputs_q_{s}_r{r}_g{g}{tag}",
                 lambda: steps.build_block_inputs_q(cfg, r, g)),
                (f"bw_calib_{s}_r{r}_g{g}{tag}",
                 lambda: steps.build_bw_calib_step(cfg, r, g)),
            ]
            for d_in, d_out in sorted({cfg.linear_shape(l) for l in M.LINEAR_NAMES}):
                items.append((
                    f"lw_calib_{s}_{d_in}x{d_out}_r{r}_g{g}{tag}",
                    lambda di=d_in, do=d_out: steps.build_lw_calib_step(cfg, di, do, r, g),
                ))
            return items

        plan.extend(per_rg())

        # DoRA variants (Tables 9/10) -- default rank/group only.
        plan.append((f"logits_q_{s}_r{r}_g{g}_dora",
                     lambda c=cfg, r=r, g=g: steps.build_logits_q(c, r, g, "dora")))
        plan.append((f"finetune_step_{s}_r{r}_g{g}_dora",
                     lambda c=cfg, r=r, g=g: steps.build_finetune_step(c, r, g, "dora")))
        plan.append((f"bw_calib_{s}_r{r}_g{g}_dora",
                     lambda c=cfg, r=r, g=g: steps.build_bw_calib_step(c, r, g, "dora")))

        # Standalone fakequant (integration tests + packing cross-check).
        for d_in, d_out in sorted({cfg.linear_shape(l) for l in M.LINEAR_NAMES}):
            key = (d_in, d_out, g)
            if key not in fq_shapes_done:
                fq_shapes_done.add(key)
                plan.append((
                    f"fakequant_{d_in}x{d_out}_g{g}",
                    lambda di=d_in, do=d_out, gg=g: steps.build_fakequant_apply(di, do, gg),
                ))

    # Table 3 group-size ablation artifacts (tiny + small, ApiQ-bw path).
    for s in [x for x in sizes if x in ("tiny", "small")]:
        cfg = M.SIZES[s]
        g2 = TABLE3_GROUP
        plan.append((f"logits_q_{s}_r{rank}_g{g2}",
                     lambda c=cfg: steps.build_logits_q(c, rank, g2)))
        plan.append((f"block_inputs_q_{s}_r{rank}_g{g2}",
                     lambda c=cfg: steps.build_block_inputs_q(c, rank, g2)))
        plan.append((f"bw_calib_{s}_r{rank}_g{g2}",
                     lambda c=cfg: steps.build_bw_calib_step(c, rank, g2)))

    # Fig. 6 rank sweep (tiny only).
    if "tiny" in sizes:
        cfg = M.SIZES["tiny"]
        for r2 in FIG6_RANKS:
            plan.append((f"logits_q_tiny_r{r2}_g{group}",
                         lambda rr=r2: steps.build_logits_q(cfg, rr, group)))
            plan.append((f"block_inputs_q_tiny_r{r2}_g{group}",
                         lambda rr=r2: steps.build_block_inputs_q(cfg, rr, group)))
            plan.append((f"bw_calib_tiny_r{r2}_g{group}",
                         lambda rr=r2: steps.build_bw_calib_step(cfg, rr, group)))
            plan.append((f"finetune_step_tiny_r{r2}_g{group}",
                         lambda rr=r2: steps.build_finetune_step(cfg, rr, group)))

    return plan


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default="tiny,small,base")
    ap.add_argument("--rank", type=int, default=DEFAULT_RANK)
    ap.add_argument("--group", type=int, default=DEFAULT_GROUP)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default="", help="comma-sep name substrings to emit")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    src_dir = os.path.dirname(os.path.abspath(__file__))
    src_mtime = max(
        os.path.getmtime(os.path.join(root, f))
        for root, _, files in os.walk(src_dir)
        for f in files
        if f.endswith(".py")
    )

    sizes = [s for s in args.sizes.split(",") if s]
    plan = artifact_plan(sizes, args.rank, args.group)
    only = [o for o in args.only.split(",") if o]
    if only:
        plan = [(n, b) for n, b in plan if any(o in n for o in only)]

    print(f"emitting {len(plan)} artifacts to {args.out}")
    for name, builder in plan:
        emit(name, builder, args.out, args.force, src_mtime)
    print("done")


if __name__ == "__main__":
    sys.exit(main())
