"""L2: the AOT-able step functions (the paper's Algorithm 1 and friends).

Every public ``build_*`` function returns ``(fn, arg_specs)`` where

  fn        : a pure function  dict -> dict  (single pytree in, pytree out)
  arg_specs : nested dict of jax.ShapeDtypeStruct mirroring fn's argument

aot.py lowers ``jax.jit(fn, keep_unused=True)`` on ``arg_specs`` to HLO
text and emits a name-ordered manifest so the Rust coordinator can bind
buffers by flat key.  Single-dict signatures keep the flattening order
deterministic (jax flattens dicts by sorted key).

Optimizer state is threaded *through* the artifacts (moments in, moments
out): Rust stays a pure orchestrator and a step is one PJRT execute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M
from .kernels import make_qlora_matmul


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def specs_of(shapes: dict[str, tuple[int, ...]]) -> dict[str, jax.ShapeDtypeStruct]:
    return {k: f32(*v) for k, v in shapes.items()}


# ---------------------------------------------------------------------------
# AdamW (in-graph, bias-corrected, decoupled weight decay)
# ---------------------------------------------------------------------------

def adamw_update(
    params: dict, grads: dict, m: dict, v: dict, t: jax.Array,
    lr: jax.Array, wd: jax.Array, lr_mul: dict | None = None,
    b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
) -> tuple[dict, dict, dict]:
    """One AdamW step over a flat dict of tensors. `t` is the 1-based step
    count (traced f32). `lr_mul` optionally scales lr per key (used for the
    Table 1 LoRA-position ablation and the theta-vs-AB split of Table A.1).
    """
    new_p, new_m, new_v = {}, {}, {}
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t
    for k in params:
        g = grads[k]
        m2 = b1 * m[k] + (1.0 - b1) * g
        v2 = b2 * v[k] + (1.0 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        step_lr = lr * lr_mul[k] if lr_mul is not None else lr
        new_p[k] = params[k] - step_lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * params[k])
        new_m[k] = m2
        new_v[k] = v2
    return new_p, new_m, new_v


def zeros_like_specs(shapes: dict[str, tuple[int, ...]]) -> dict[str, jax.ShapeDtypeStruct]:
    return {k: f32(*v) for k, v in shapes.items()}


# ---------------------------------------------------------------------------
# Pretraining step (creates the "pretrained LLM" substrate, DESIGN.md §3)
# ---------------------------------------------------------------------------

def build_pretrain_step(cfg: M.ModelConfig):
    pshapes = M.param_specs(cfg)

    def fn(args):
        params, m, v = args["params"], args["m"], args["v"]

        def loss_fn(p):
            logits = M.model_forward(cfg, p, args["tokens"], mode="fp")
            return M.next_token_loss(cfg, logits, args["tokens"], args["mask"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        p2, m2, v2 = adamw_update(params, grads, m, v, args["t"], args["lr"], args["wd"])
        return {"params": p2, "m": m2, "v": v2, "loss": loss}

    arg_specs = {
        "params": specs_of(pshapes),
        "m": specs_of(pshapes),
        "v": specs_of(pshapes),
        "tokens": i32(cfg.batch, cfg.seq_len),
        "mask": f32(cfg.batch, cfg.seq_len),
        "t": f32(),
        "lr": f32(),
        "wd": f32(),
    }
    return fn, arg_specs


# ---------------------------------------------------------------------------
# Full-model logits (eval fwd; Rust computes ppl / accuracy host-side)
# ---------------------------------------------------------------------------

def build_logits_fp(cfg: M.ModelConfig):
    pshapes = M.param_specs(cfg)

    def fn(args):
        logits = M.model_forward(cfg, args["params"], args["tokens"], mode="fp")
        return {"logits": logits.reshape(cfg.batch, cfg.seq_len, cfg.vocab)}

    arg_specs = {
        "params": specs_of(pshapes),
        "tokens": i32(cfg.batch, cfg.seq_len),
    }
    return fn, arg_specs


def build_logits_q(cfg: M.ModelConfig, rank: int, group: int, adapter: str = "lora"):
    pshapes = M.param_specs(cfg)
    qshapes = M.qparam_specs(cfg, rank, group, adapter)

    def fn(args):
        logits = M.model_forward(
            cfg, args["params"], args["tokens"], mode=adapter,
            qparams=args["qparams"], bits=args["bits"], scale=args["scale"],
            group=group,
        )
        return {"logits": logits.reshape(cfg.batch, cfg.seq_len, cfg.vocab)}

    arg_specs = {
        "params": specs_of(pshapes),
        "qparams": specs_of(qshapes),
        "tokens": i32(cfg.batch, cfg.seq_len),
        "bits": f32(),
        "scale": f32(),
    }
    return fn, arg_specs


# ---------------------------------------------------------------------------
# LoRA / DoRA finetuning step on the frozen quantized model (QLoRA-style)
# ---------------------------------------------------------------------------

TRAINABLE_SUFFIXES = {"lora": ("lora_a", "lora_b"), "dora": ("lora_a", "lora_b", "mag")}
ATTN_LINEARS = ("wq", "wk", "wv", "wo")


def build_finetune_step(cfg: M.ModelConfig, rank: int, group: int, adapter: str = "lora"):
    pshapes = M.param_specs(cfg)
    qshapes = M.qparam_specs(cfg, rank, group, adapter)
    suffixes = TRAINABLE_SUFFIXES[adapter]
    train_keys = [k for k in qshapes if k.rsplit(".", 1)[1] in suffixes]
    tshapes = {k: qshapes[k] for k in train_keys}

    def lin_of(key: str) -> str:
        return key.split(".")[2]  # blocks.{i}.{lin}.{suffix}

    def fn(args):
        qparams, m, v = args["qparams"], args["m"], args["v"]

        def loss_fn(train_sub):
            qp = dict(qparams)
            qp.update(train_sub)
            logits = M.model_forward(
                cfg, args["params"], args["tokens"], mode=adapter, qparams=qp,
                bits=args["bits"], scale=args["scale"], group=group,
            )
            return M.next_token_loss(cfg, logits, args["tokens"], args["mask"])

        train_sub = {k: qparams[k] for k in train_keys}
        loss, grads = jax.value_and_grad(loss_fn)(train_sub)
        # Table 1 ablation: per-position LR multipliers (0 freezes a group).
        lr_mul = {
            k: args["lr_attn_mul"] if lin_of(k) in ATTN_LINEARS else args["lr_ffn_mul"]
            for k in train_keys
        }
        p2, m2, v2 = adamw_update(
            train_sub, grads, m, v, args["t"], args["lr"], args["wd"], lr_mul=lr_mul
        )
        q2 = dict(qparams)
        q2.update(p2)
        return {"qparams": q2, "m": m2, "v": v2, "loss": loss}

    arg_specs = {
        "params": specs_of(pshapes),
        "qparams": specs_of(qshapes),
        "m": specs_of(tshapes),
        "v": specs_of(tshapes),
        "tokens": i32(cfg.batch, cfg.seq_len),
        "mask": f32(cfg.batch, cfg.seq_len),
        "t": f32(),
        "lr": f32(),
        "wd": f32(),
        "bits": f32(),
        "scale": f32(),
        "lr_attn_mul": f32(),
        "lr_ffn_mul": f32(),
    }
    return fn, arg_specs


# ---------------------------------------------------------------------------
# Block-granular forwards (calibration streams + Fig. 4 metrics)
# ---------------------------------------------------------------------------

ACT_KEYS = ("attn_in", "o_in", "ffn_in", "down_in", "attn_out", "ffn_out")


def build_embed_fwd(cfg: M.ModelConfig):
    def fn(args):
        return {"x": jnp.take(args["embed"], args["tokens"], axis=0)}

    arg_specs = {
        "embed": f32(cfg.vocab, cfg.d_model),
        "tokens": i32(cfg.calib_batch, cfg.seq_len),
    }
    return fn, arg_specs


def build_block_inputs_fp(cfg: M.ModelConfig):
    bshapes = M.block_param_specs(cfg)

    def fn(args):
        linear = M.make_linear("fp", None, None, None, 64)
        out, acts = M.block_forward(cfg, args["bp"], args["x"], linear, collect=True)
        return {"out": out, **{k: acts[k] for k in ACT_KEYS}}

    arg_specs = {
        "bp": specs_of(bshapes),
        "x": f32(cfg.calib_batch, cfg.seq_len, cfg.d_model),
    }
    return fn, arg_specs


def build_block_inputs_q(cfg: M.ModelConfig, rank: int, group: int, adapter: str = "lora"):
    bshapes = M.block_param_specs(cfg)
    bqshapes = M.block_qparam_specs(cfg, rank, group, adapter)

    def fn(args):
        linear = M.make_linear(
            adapter, args["bqp"], args["bits"], args["scale"], group, prefix=""
        )
        out, acts = M.block_forward(cfg, args["bp"], args["x"], linear, collect=True)
        return {"out": out, **{k: acts[k] for k in ACT_KEYS}}

    arg_specs = {
        "bp": specs_of(bshapes),
        "bqp": specs_of(bqshapes),
        "x": f32(cfg.calib_batch, cfg.seq_len, cfg.d_model),
        "bits": f32(),
        "scale": f32(),
    }
    return fn, arg_specs


# ---------------------------------------------------------------------------
# ApiQ-lw calibration step (Algorithm 1, one linear layer)
# ---------------------------------------------------------------------------

LW_QP_KEYS = ("gamma", "beta", "lora_a", "lora_b")


def build_lw_calib_step(cfg: M.ModelConfig, d_in: int, d_out: int, rank: int, group: int):
    """One gradient step of Eq. (4) for a (d_in, d_out) linear layer.

    Inputs X / X^q arrive as (calib_tokens, d_in); the target Y = X·W is
    computed in-graph (no grad).  Trainables: gamma, beta, lora_a, lora_b,
    with the paper's separate LR/WD for Θ={γ,β} vs {A,B} (Table A.1).
    Setting lr_ab = 0 degrades this exactly to OmniQuant-lite (learnable
    clipping without LoRA) -- the Table 3 baseline.
    """
    n_tok = cfg.calib_batch * cfg.seq_len
    qp_shapes = {
        "gamma": (d_in // group, d_out),
        "beta": (d_in // group, d_out),
        "lora_a": (d_in, rank),
        "lora_b": (d_out, rank),
    }
    qm = make_qlora_matmul(group)

    def fn(args):
        w = args["w"]
        y = jax.lax.stop_gradient(args["x"] @ w)

        def loss_fn(qp):
            yq = qm(args["xq"], w, qp["gamma"], qp["beta"], qp["lora_a"],
                    qp["lora_b"], args["bits"], args["scale"])
            return jnp.mean((y - yq) ** 2)

        qp = {k: args["qp"][k] for k in LW_QP_KEYS}
        loss, grads = jax.value_and_grad(loss_fn)(qp)
        lr_mul = {
            "gamma": args["lr_gb"], "beta": args["lr_gb"],
            "lora_a": args["lr_ab"], "lora_b": args["lr_ab"],
        }
        wd_mul = {
            "gamma": args["wd_gb"], "beta": args["wd_gb"],
            "lora_a": args["wd_ab"], "lora_b": args["wd_ab"],
        }
        # AdamW with per-group lr and wd: fold wd into the update manually.
        new_qp, new_m, new_v = {}, {}, {}
        bc1 = 1.0 - 0.9 ** args["t"]
        bc2 = 1.0 - 0.999 ** args["t"]
        for k in LW_QP_KEYS:
            g = grads[k]
            m2 = 0.9 * args["m"][k] + 0.1 * g
            v2 = 0.999 * args["v"][k] + 0.001 * g * g
            upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + 1e-8) + wd_mul[k] * qp[k]
            new_qp[k] = qp[k] - lr_mul[k] * upd
            new_m[k] = m2
            new_v[k] = v2
        return {"qp": new_qp, "m": new_m, "v": new_v, "loss": loss}

    arg_specs = {
        "w": f32(d_in, d_out),
        "qp": specs_of(qp_shapes),
        "m": specs_of(qp_shapes),
        "v": specs_of(qp_shapes),
        "x": f32(n_tok, d_in),
        "xq": f32(n_tok, d_in),
        "t": f32(),
        "lr_ab": f32(),
        "lr_gb": f32(),
        "wd_ab": f32(),
        "wd_gb": f32(),
        "bits": f32(),
        "scale": f32(),
    }
    return fn, arg_specs


# ---------------------------------------------------------------------------
# ApiQ-bw calibration step (whole transformer block, §4.2)
# ---------------------------------------------------------------------------

def build_bw_calib_step(cfg: M.ModelConfig, rank: int, group: int, adapter: str = "lora"):
    bshapes = M.block_param_specs(cfg)
    bqshapes = M.block_qparam_specs(cfg, rank, group, adapter)
    suffixes = ("gamma", "beta") + TRAINABLE_SUFFIXES[adapter]
    train_keys = [k for k in bqshapes if k.rsplit(".", 1)[1] in suffixes]
    tshapes = {k: bqshapes[k] for k in train_keys}

    def fn(args):
        bp = args["bp"]
        linear_fp = M.make_linear("fp", None, None, None, group)
        y = jax.lax.stop_gradient(M.block_forward(cfg, bp, args["x"], linear_fp))

        def loss_fn(train_sub):
            bqp = dict(args["bqp"])
            bqp.update(train_sub)
            linear_q = M.make_linear(adapter, bqp, args["bits"], args["scale"], group)
            yq = M.block_forward(cfg, bp, args["xq"], linear_q)
            return jnp.mean((y - yq) ** 2)

        train_sub = {k: args["bqp"][k] for k in train_keys}
        loss, grads = jax.value_and_grad(loss_fn)(train_sub)

        def is_theta(k: str) -> bool:
            return k.rsplit(".", 1)[1] in ("gamma", "beta")

        new_p, new_m, new_v = {}, {}, {}
        bc1 = 1.0 - 0.9 ** args["t"]
        bc2 = 1.0 - 0.999 ** args["t"]
        for k in train_keys:
            g = grads[k]
            m2 = 0.9 * args["m"][k] + 0.1 * g
            v2 = 0.999 * args["v"][k] + 0.001 * g * g
            lr = args["lr_gb"] if is_theta(k) else args["lr_ab"]
            wd = args["wd_gb"] if is_theta(k) else args["wd_ab"]
            upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + 1e-8) + wd * train_sub[k]
            new_p[k] = train_sub[k] - lr * upd
            new_m[k] = m2
            new_v[k] = v2
        bqp2 = dict(args["bqp"])
        bqp2.update(new_p)
        return {"bqp": bqp2, "m": new_m, "v": new_v, "loss": loss}

    arg_specs = {
        "bp": specs_of(bshapes),
        "bqp": specs_of(bqshapes),
        "m": specs_of(tshapes),
        "v": specs_of(tshapes),
        "x": f32(cfg.calib_batch, cfg.seq_len, cfg.d_model),
        "xq": f32(cfg.calib_batch, cfg.seq_len, cfg.d_model),
        "t": f32(),
        "lr_ab": f32(),
        "lr_gb": f32(),
        "wd_ab": f32(),
        "wd_gb": f32(),
        "bits": f32(),
        "scale": f32(),
    }
    return fn, arg_specs


# ---------------------------------------------------------------------------
# Standalone fakequant apply (Rust integration tests + final packing check)
# ---------------------------------------------------------------------------

def build_fakequant_apply(d_in: int, d_out: int, group: int):
    from .kernels import make_fakequant

    fq = make_fakequant(group)

    def fn(args):
        return {"q": fq(args["w"], args["gamma"], args["beta"], args["bits"])}

    arg_specs = {
        "w": f32(d_in, d_out),
        "gamma": f32(d_in // group, d_out),
        "beta": f32(d_in // group, d_out),
        "bits": f32(),
    }
    return fn, arg_specs
