"""L2: the JAX model family (TinyLlama) and its quantized-LoRA variants.

Decoder-only Llama-2-style transformer: RMSNorm, rotary position
embeddings, causal multi-head attention, SwiGLU FFN, untied LM head.
Every linear layer can run in three modes:

  fp    : y = x @ W                              (pretraining, fp stream)
  lora  : y = x @ (fakequant(W) + s·A·Bᵀ)        (fused L1 Pallas kernel)
  dora  : y = x @ (m ⊙ (Q + s·A·Bᵀ)/‖·‖_col)     (Table 9/10 adapter)

Parameters live in *flat string-keyed dicts* so that the Rust coordinator
can bind buffers by name (see aot.py manifest emission).  Keys:

  embed                       (V, d)
  blocks.{i}.attn_norm        (d,)
  blocks.{i}.{wq|wk|wv|wo}    (d, d)
  blocks.{i}.ffn_norm         (d,)
  blocks.{i}.{wgate|wup}      (d, f)
  blocks.{i}.wdown            (f, d)
  final_norm                  (d,)
  lm_head                     (d, V)

Quant/adapter params for linear `L` of block `i` (group g, rank r):

  blocks.{i}.{L}.gamma        (d_in/g, d_out)
  blocks.{i}.{L}.beta         (d_in/g, d_out)
  blocks.{i}.{L}.lora_a       (d_in, r)
  blocks.{i}.{L}.lora_b       (d_out, r)
  blocks.{i}.{L}.mag          (d_out,)          [dora only]
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import make_qlora_matmul
from .kernels import ref as kref

LINEAR_NAMES = ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown")
# Calibration order within a block, per the paper (§4.1): q,k,v -> o ->
# gate,up -> down.  Stages share the same input activation.
CALIB_STAGES = (("wq", "wk", "wv"), ("wo",), ("wgate", "wup"), ("wdown",))


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ffn: int
    seq_len: int         # T baked into all artifacts of this size
    batch: int           # train/eval batch baked into step artifacts
    calib_batch: int     # calibration sequences per calib-step call

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def linear_shape(self, name: str) -> tuple[int, int]:
        d, f = self.d_model, self.d_ffn
        return {
            "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
            "wgate": (d, f), "wup": (d, f), "wdown": (f, d),
        }[name]


# The family reproduces the paper's 7B-vs-13B axis at laptop scale; `base`
# is the ~100M end-to-end validation model (DESIGN.md §3, §6).
SIZES: dict[str, ModelConfig] = {
    "tiny": ModelConfig("tiny", vocab=512, d_model=256, n_layers=4, n_heads=4,
                        d_ffn=768, seq_len=128, batch=8, calib_batch=8),
    "small": ModelConfig("small", vocab=2048, d_model=512, n_layers=8, n_heads=8,
                         d_ffn=1408, seq_len=256, batch=4, calib_batch=4),
    "base": ModelConfig("base", vocab=4096, d_model=768, n_layers=12, n_heads=12,
                        d_ffn=2176, seq_len=256, batch=2, calib_batch=2),
}


# ---------------------------------------------------------------------------
# Parameter spec construction (shapes only; init happens in Rust).
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    """Flat name -> shape for the full-precision model parameters."""
    s: dict[str, tuple[int, ...]] = {
        "embed": (cfg.vocab, cfg.d_model),
        "final_norm": (cfg.d_model,),
        "lm_head": (cfg.d_model, cfg.vocab),
    }
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        s[p + "attn_norm"] = (cfg.d_model,)
        s[p + "ffn_norm"] = (cfg.d_model,)
        for lin in LINEAR_NAMES:
            s[p + lin] = cfg.linear_shape(lin)
    return s


def qparam_specs(
    cfg: ModelConfig, rank: int, group: int, adapter: str = "lora"
) -> dict[str, tuple[int, ...]]:
    """Flat name -> shape for quantization + adapter parameters."""
    s: dict[str, tuple[int, ...]] = {}
    for i in range(cfg.n_layers):
        for lin in LINEAR_NAMES:
            d_in, d_out = cfg.linear_shape(lin)
            p = f"blocks.{i}.{lin}."
            s[p + "gamma"] = (d_in // group, d_out)
            s[p + "beta"] = (d_in // group, d_out)
            s[p + "lora_a"] = (d_in, rank)
            s[p + "lora_b"] = (d_out, rank)
            if adapter == "dora":
                s[p + "mag"] = (d_out,)
    return s


def block_param_specs(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    """Per-block fp params with block-local names (no `blocks.{i}.`)."""
    s: dict[str, tuple[int, ...]] = {
        "attn_norm": (cfg.d_model,), "ffn_norm": (cfg.d_model,),
    }
    for lin in LINEAR_NAMES:
        s[lin] = cfg.linear_shape(lin)
    return s


def block_qparam_specs(
    cfg: ModelConfig, rank: int, group: int, adapter: str = "lora"
) -> dict[str, tuple[int, ...]]:
    s: dict[str, tuple[int, ...]] = {}
    for lin in LINEAR_NAMES:
        d_in, d_out = cfg.linear_shape(lin)
        s[f"{lin}.gamma"] = (d_in // group, d_out)
        s[f"{lin}.beta"] = (d_in // group, d_out)
        s[f"{lin}.lora_a"] = (d_in, rank)
        s[f"{lin}.lora_b"] = (d_out, rank)
        if adapter == "dora":
            s[f"{lin}.mag"] = (d_out,)
    return s


# ---------------------------------------------------------------------------
# Linear-layer modes
# ---------------------------------------------------------------------------

def linear_fp(x2d: jax.Array, w: jax.Array) -> jax.Array:
    return x2d @ w


def linear_qlora(
    x2d: jax.Array, w: jax.Array, qp: dict[str, jax.Array],
    bits: jax.Array, scale: jax.Array, group: int,
) -> jax.Array:
    """Quantized + LoRA linear through the fused L1 Pallas kernel."""
    qm = make_qlora_matmul(group)
    return qm(x2d, w, qp["gamma"], qp["beta"], qp["lora_a"], qp["lora_b"], bits, scale)


def linear_qdora(
    x2d: jax.Array, w: jax.Array, qp: dict[str, jax.Array],
    bits: jax.Array, scale: jax.Array, group: int,
) -> jax.Array:
    """Quantized + DoRA linear (magnitude/direction decomposition)."""
    return kref.dora_matmul_ref(
        x2d, w, qp["gamma"], qp["beta"], qp["lora_a"], qp["lora_b"], qp["mag"],
        bits, scale, group,
    )


def make_linear(mode: str, qparams: dict[str, jax.Array] | None,
                bits: jax.Array | None, scale: jax.Array | None,
                group: int, prefix: str = ""):
    """Returns linear(name, x2d, w) for the requested mode.

    `qparams` is a flat dict; `prefix` selects the block (e.g. "blocks.3.")
    or "" when qparams already uses block-local names.
    """
    if mode == "fp":
        return lambda name, x2d, w: linear_fp(x2d, w)

    fn = {"lora": linear_qlora, "dora": linear_qdora}[mode]

    def linear(name: str, x2d: jax.Array, w: jax.Array) -> jax.Array:
        keys = ("gamma", "beta", "lora_a", "lora_b", "mag")
        qp = {k: qparams[f"{prefix}{name}.{k}"]
              for k in keys if f"{prefix}{name}.{k}" in qparams}
        return fn(x2d, w, qp, bits, scale, group)

    return linear


# ---------------------------------------------------------------------------
# Transformer building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-5) * w


@functools.lru_cache(maxsize=None)
def _rope_tables(seq_len: int, head_dim: int):
    # numpy (not jnp) so the tables embed as HLO constants; concrete
    # jax.Arrays would be hoisted into extra lowered parameters, breaking
    # the artifact manifest contract.
    import numpy as np

    pos = np.arange(seq_len, dtype=np.float32)[:, None]
    inv = 1.0 / (10000.0 ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))
    ang = pos * inv[None, :]                      # (T, hd/2)
    return np.cos(ang), np.sin(ang)


def apply_rope(x: jax.Array) -> jax.Array:
    """x: (B, H, T, hd) -> rotated. Pairs are (even, odd) interleaved."""
    _, _, t, hd = x.shape
    cos, sin = _rope_tables(t, hd)
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def block_forward(
    cfg: ModelConfig, bp: dict[str, jax.Array], x: jax.Array, linear,
    prefix: str = "", collect: bool = False,
):
    """One transformer block.  x: (B, T, d).

    With collect=True also returns the inputs to each linear layer (the
    X / X^q activations ApiQ's calibration needs, in CALIB_STAGES order)
    and the attention/FFN branch outputs -- the coordinator uses these for
    Algorithm 1 and for the Fig. 4 activation-error metric.
    """
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    g = lambda name: bp[prefix + name]

    attn_in = rmsnorm(x, g("attn_norm"))              # input to wq/wk/wv
    a2 = attn_in.reshape(b * t, d)
    q = linear("wq", a2, g("wq")).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = linear("wk", a2, g("wk")).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = linear("wv", a2, g("wv")).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q)
    k = apply_rope(k)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=jnp.float32))
    scores = jnp.where(mask[None, None] > 0, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    o_in = ctx.transpose(0, 2, 1, 3).reshape(b, t, d)  # input to wo
    attn_out = linear("wo", o_in.reshape(b * t, d), g("wo")).reshape(b, t, d)
    x1 = x + attn_out

    ffn_in = rmsnorm(x1, g("ffn_norm"))               # input to wgate/wup
    f2 = ffn_in.reshape(b * t, d)
    gate = linear("wgate", f2, g("wgate"))
    up = linear("wup", f2, g("wup"))
    down_in = (jax.nn.silu(gate) * up).reshape(b, t, cfg.d_ffn)  # input to wdown
    ffn_out = linear("wdown", down_in.reshape(b * t, cfg.d_ffn),
                     g("wdown")).reshape(b, t, d)
    out = x1 + ffn_out

    if not collect:
        return out
    acts = {
        "attn_in": attn_in,   # X for wq, wk, wv
        "o_in": o_in,         # X for wo
        "ffn_in": ffn_in,     # X for wgate, wup
        "down_in": down_in,   # X for wdown
        "attn_out": attn_out,
        "ffn_out": ffn_out,
    }
    return out, acts


def model_forward(
    cfg: ModelConfig, params: dict[str, jax.Array], tokens: jax.Array,
    mode: str = "fp", qparams: dict[str, jax.Array] | None = None,
    bits: jax.Array | None = None, scale: jax.Array | None = None,
    group: int = 64,
) -> jax.Array:
    """tokens: (B, T) int32 -> logits (B*T, V)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    for i in range(cfg.n_layers):
        prefix = f"blocks.{i}."
        linear = make_linear(mode, qparams, bits, scale, group, prefix=prefix)
        x = block_forward(cfg, params, x, linear, prefix=prefix)
    x = rmsnorm(x, params["final_norm"])
    return x.reshape(-1, cfg.d_model) @ params["lm_head"]


def next_token_loss(
    cfg: ModelConfig, logits: jax.Array, tokens: jax.Array, mask: jax.Array
) -> jax.Array:
    """Masked next-token cross entropy.

    logits: (B*T, V); tokens: (B, T); mask: (B, T) weighting the *target*
    position t (i.e. mask[b, t] applies to predicting tokens[b, t] from
    position t-1).  Positions 0 and padding get mask 0 from the Rust side.
    """
    b, t = tokens.shape
    logp = jax.nn.log_softmax(logits.reshape(b, t, cfg.vocab), axis=-1)
    pred = logp[:, :-1, :]                                # predicts t=1..T-1
    tgt = tokens[:, 1:]
    m = mask[:, 1:]
    nll = -jnp.take_along_axis(pred, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
