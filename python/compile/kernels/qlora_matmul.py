"""L1 Pallas kernel: fused quantized-LoRA linear layer,

    y = x @ (fakequant(W; gamma, beta, bits) + scale * A @ B^T)

This is the request-path hot-spot of the reproduced system: every linear in
the quantized model forward (PTQ eval, LoRA finetuning, activation-error
metrics) goes through it.

TPU schedule expressed by the BlockSpecs: grid cell (i, j) produces output
tile (block_m, block_n).  It streams the full reduction dimension of X, W,
A through VMEM, fake-quantizes W column-block-locally (whole groups -- the
group axis is the reduction axis, so a column block contains complete
groups), computes the base MXU matmul x @ q, and fuses the low-rank
correction as a second pair of skinny matmuls (x @ A) @ B_tile^T.  On a
real TPU both matmuls hit the 128x128 systolic array in bf16; here
(interpret=True, CPU) the same structure lowers to fused HLO dots.

Backward: custom_vjp via the jnp reference (STE semantics), fused by XLA
into the calibration/finetune step HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _qlora_kernel(
    x_ref, w_ref, gamma_ref, beta_ref, a_ref, b_ref, bits_ref, scale_ref, o_ref, *, group: int
):
    """One grid cell: output tile (block_m, block_n).

    x_ref : (block_m, d_in)        w_ref : (d_in, block_n)
    gamma_ref/beta_ref : (d_in//group, block_n)
    a_ref : (d_in, r)              b_ref : (block_n, r)
    bits_ref, scale_ref : (1, 1)
    """
    x = x_ref[...]
    w = w_ref[...]
    d_in, cols = w.shape
    gpb = d_in // group
    wg = w.reshape(gpb, group, cols)

    wmax = jnp.max(wg, axis=1)
    wmin = jnp.min(wg, axis=1)
    hi = jax.nn.sigmoid(gamma_ref[...]) * wmax
    lo = jax.nn.sigmoid(beta_ref[...]) * wmin
    m_levels = 2.0 ** bits_ref[0, 0] - 1.0
    s = jnp.maximum((hi - lo) / m_levels, 1e-8)
    z = jnp.clip(jnp.round(-lo / s), 0.0, m_levels)
    s3 = s[:, None, :]
    z3 = z[:, None, :]
    q = (s3 * (jnp.clip(jnp.round(wg / s3) + z3, 0.0, m_levels) - z3)).reshape(d_in, cols)

    # Base matmul + fused low-rank correction (low-rank-first ordering).
    base = jnp.dot(x, q)
    corr = jnp.dot(jnp.dot(x, a_ref[...]), b_ref[...].T)
    o_ref[...] = base + scale_ref[0, 0] * corr


def qlora_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    a: jax.Array,
    b: jax.Array,
    bits: jax.Array,
    scale: jax.Array,
    *,
    group: int,
    block_m: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Forward-only fused kernel. x: (m, d_in) -> (m, d_out)."""
    m, d_in = x.shape
    _, d_out = w.shape
    r = a.shape[1]
    block_m = block_m or m
    block_n = block_n or d_out
    grid = (m // block_m, d_out // block_n)
    gpc = d_in // group
    bits2 = jnp.reshape(bits.astype(jnp.float32), (1, 1))
    scale2 = jnp.reshape(scale.astype(jnp.float32), (1, 1))

    return pl.pallas_call(
        functools.partial(_qlora_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((d_in, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((gpc, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((gpc, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((d_in, r), lambda i, j: (0, 0)),
            pl.BlockSpec((block_n, r), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d_out), x.dtype),
        interpret=True,
    )(x, w, gamma, beta, a, b, bits2, scale2)


@functools.lru_cache(maxsize=None)
def make_qlora_matmul(group: int, block_m: int | None = None, block_n: int | None = None):
    """Differentiable fused quantized-LoRA matmul for a given group size.

    Pallas forward; backward = VJP of the jnp reference (STE through the
    quantizer, exact gradients for x, A, B, gamma, beta).
    """

    @jax.custom_vjp
    def qlora_matmul(x, w, gamma, beta, a, b, bits, scale):
        return qlora_matmul_pallas(
            x, w, gamma, beta, a, b, bits, scale,
            group=group, block_m=block_m, block_n=block_n,
        )

    def _fwd(x, w, gamma, beta, a, b, bits, scale):
        return qlora_matmul(x, w, gamma, beta, a, b, bits, scale), (
            x, w, gamma, beta, a, b, bits, scale,
        )

    def _bwd(res, ct):
        x, w, gamma, beta, a, b, bits, scale = res
        _, vjp = jax.vjp(
            lambda x_, w_, g_, be_, a_, b_: ref.qlora_matmul_ref(
                x_, w_, g_, be_, a_, b_, bits, scale, group
            ),
            x, w, gamma, beta, a, b,
        )
        dx, dw, dg, dbe, da, db = vjp(ct)
        return dx, dw, dg, dbe, da, db, jnp.zeros_like(bits), jnp.zeros_like(scale)

    qlora_matmul.defvjp(_fwd, _bwd)
    return qlora_matmul
