"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the *semantics* of the kernels: every Pallas kernel in this
package must match its reference here to ~1e-5 (checked by pytest +
hypothesis in python/tests/test_kernel.py). They are also used as the
backward rule of the kernels' ``jax.custom_vjp`` wrappers, which gives the
exact straight-through-estimator (STE) gradients the ApiQ paper's
Algorithm 1 requires (round is an identity in the backward pass, clipping
masks the gradient).

Conventions (match the paper, §2 and §4):
  W  : (d_in, d_out)   -- activations are row vectors, y = x @ W
  A  : (d_in, r), B : (d_out, r), low-rank term A @ B^T
  gamma, beta : (d_in // group, d_out) learnable clipping logits; the
      effective clip range is [sigmoid(beta)*min_g(W), sigmoid(gamma)*max_g(W)]
      per quantization group (a group = `group` consecutive input rows of
      one output column, as in OmniQuant / the paper's "group size 64").
  bits : a *traced* f32 scalar so one AOT artifact serves b in {2,3,4,16};
      bits=16 makes fakequant a near-identity (used to route host-side
      dequantized baselines through the same HLO).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ste_round(x: jax.Array) -> jax.Array:
    """Round with a straight-through gradient (Bengio et al., 2013)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def group_minmax(w: jax.Array, group: int) -> tuple[jax.Array, jax.Array]:
    """Per-group (min, max) over `group` consecutive rows of each column.

    w: (d_in, d_out) -> each of shape (d_in // group, d_out).
    The row extrema are treated as stop-gradient constants: the clipping
    *range* is controlled by gamma/beta, not by moving the extrema (same
    choice as OmniQuant's learnable clipping).
    """
    d_in, d_out = w.shape
    assert d_in % group == 0, f"d_in={d_in} not divisible by group={group}"
    wg = w.reshape(d_in // group, group, d_out)
    wmax = jax.lax.stop_gradient(jnp.max(wg, axis=1))
    wmin = jax.lax.stop_gradient(jnp.min(wg, axis=1))
    return wmin, wmax


def quant_params(
    w: jax.Array, gamma: jax.Array, beta: jax.Array, bits: jax.Array, group: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Scale s, zero-point z and max level M for the paper's Eq. (1)/(3)
    with the learnable clipping of §4.3.

    Returns (s, z, M): s, z of shape (d_in//group, d_out), M scalar.
    z is kept fractional under STE (rounded in fwd, identity in bwd) and
    clamped to the representable range [0, M].
    """
    wmin, wmax = group_minmax(w, group)
    hi = jax.nn.sigmoid(gamma) * wmax
    lo = jax.nn.sigmoid(beta) * wmin
    m_levels = 2.0**bits - 1.0
    s = jnp.maximum((hi - lo) / m_levels, 1e-8)
    z = jnp.clip(ste_round(-lo / s), 0.0, m_levels)
    return s, z, m_levels


def fakequant_ref(
    w: jax.Array, gamma: jax.Array, beta: jax.Array, bits: jax.Array, group: int
) -> jax.Array:
    """Quantize-dequantize (Eq. 3) with learnable clipping, group-wise.

    Q = s * (clamp(round(W/s) + z, 0, 2^b - 1) - z)
    Differentiable everywhere via STE; gradients flow to gamma/beta through
    s and z, and to W as a pass-through masked by the clip range.
    """
    d_in, d_out = w.shape
    s, z, m_levels = quant_params(w, gamma, beta, bits, group)
    wg = w.reshape(d_in // group, group, d_out)
    s3 = s[:, None, :]
    z3 = z[:, None, :]
    q = jnp.clip(ste_round(wg / s3) + z3, 0.0, m_levels)
    qd = s3 * (q - z3)
    return qd.reshape(d_in, d_out)


def lora_matmul_ref(
    x: jax.Array, q: jax.Array, a: jax.Array, b: jax.Array, scale: jax.Array
) -> jax.Array:
    """y = x @ (Q + scale * A @ B^T), computed low-rank-first.

    x: (m, d_in); q: (d_in, d_out); a: (d_in, r); b: (d_out, r).
    """
    return x @ q + (x @ a) @ b.T * scale


def qlora_matmul_ref(
    x: jax.Array,
    w: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    a: jax.Array,
    b: jax.Array,
    bits: jax.Array,
    scale: jax.Array,
    group: int,
) -> jax.Array:
    """Fused quantized-LoRA linear: y = x @ (fakequant(W) + scale*A@B^T).

    This is the paper's quantized forward (QLoRA-style linear) and the
    target of the fused L1 kernel.
    """
    q = fakequant_ref(w, gamma, beta, bits, group)
    return lora_matmul_ref(x, q, a, b, scale)


def dora_matmul_ref(
    x: jax.Array,
    w: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    a: jax.Array,
    b: jax.Array,
    mag: jax.Array,
    bits: jax.Array,
    scale: jax.Array,
    group: int,
) -> jax.Array:
    """DoRA (Liu et al., 2024) on a quantized base: the merged weight is
    decomposed into column direction and a trainable magnitude `mag`:

        W' = mag * (Q + scale*A@B^T) / ||Q + scale*A@B^T||_col

    Used for the Table 9/10 reproduction (ApiQ-bw with DoRA vs QDoRA).
    """
    q = fakequant_ref(w, gamma, beta, bits, group)
    merged = q + a @ b.T * scale
    col_norm = jnp.sqrt(jnp.sum(merged * merged, axis=0, keepdims=True) + 1e-8)
    return x @ (merged * (mag[None, :] / col_norm))
