"""L1 Pallas kernels (build-time only; lowered into L2 HLO artifacts)."""

from . import ref
from .fakequant import fakequant_pallas, make_fakequant
from .qlora_matmul import qlora_matmul_pallas, make_qlora_matmul

__all__ = [
    "ref",
    "fakequant_pallas",
    "make_fakequant",
    "qlora_matmul_pallas",
    "make_qlora_matmul",
]
