"""L1 Pallas kernel: group-wise uniform-affine fake quantization with
learnable clipping (the compute core of ApiQ's Algorithm 1, lines 6-8).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's reference
implementation does this on GPU with per-tensor CUDA ops; on TPU the right
shape is a VMEM-resident tile that contains *whole quantization groups*, so
min/max reduction, scale/zero computation and clamp-round-dequant never
leave the scratchpad.  The BlockSpec below expresses exactly that schedule:
grid cell (i, j) owns rows [i*gpb*group, (i+1)*gpb*group) x columns
[j*block_n, (j+1)*block_n), i.e. `gpb` complete groups per cell.

On this CPU image the kernel runs under ``interpret=True`` (real-TPU Pallas
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute);
the default block sizes therefore cover the whole array (grid=1), which
lowers to clean fused HLO with no while-loop overhead.  The TPU-tuned tile
sizes are documented in DESIGN.md §Perf.

Gradient rule: ``jax.custom_vjp`` whose backward is the VJP of the pure-jnp
reference (kernels/ref.py).  That reference implements the straight-through
estimator, so the backward is the paper's STE by construction and XLA fuses
it into the surrounding calibration-step HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _fakequant_kernel(w_ref, gamma_ref, beta_ref, bits_ref, o_ref, *, group: int):
    """One grid cell: fake-quantize a (gpb*group, block_n) tile of W.

    w_ref     : (gpb*group, block_n) tile of the weight
    gamma_ref : (gpb, block_n) clipping logits for the tile's groups
    beta_ref  : (gpb, block_n)
    bits_ref  : (1, 1) traced bit-width (f32)
    o_ref     : (gpb*group, block_n) dequantized output tile
    """
    w = w_ref[...]
    rows, cols = w.shape
    gpb = rows // group
    wg = w.reshape(gpb, group, cols)

    # Per-group extrema; the clip *range* is then modulated by sigmoid(γ/β).
    wmax = jnp.max(wg, axis=1)
    wmin = jnp.min(wg, axis=1)
    hi = jax.nn.sigmoid(gamma_ref[...]) * wmax
    lo = jax.nn.sigmoid(beta_ref[...]) * wmin

    m_levels = 2.0 ** bits_ref[0, 0] - 1.0
    s = jnp.maximum((hi - lo) / m_levels, 1e-8)
    z = jnp.clip(jnp.round(-lo / s), 0.0, m_levels)

    s3 = s[:, None, :]
    z3 = z[:, None, :]
    q = jnp.clip(jnp.round(wg / s3) + z3, 0.0, m_levels)
    o_ref[...] = (s3 * (q - z3)).reshape(rows, cols)


def fakequant_pallas(
    w: jax.Array,
    gamma: jax.Array,
    beta: jax.Array,
    bits: jax.Array,
    *,
    group: int,
    block_rows: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Forward-only Pallas fake-quant. See module docstring for tiling."""
    d_in, d_out = w.shape
    block_rows = block_rows or d_in
    block_n = block_n or d_out
    assert block_rows % group == 0, "tile height must hold whole groups"
    gpb = block_rows // group
    grid = (d_in // block_rows, d_out // block_n)
    bits2 = jnp.reshape(bits.astype(jnp.float32), (1, 1))

    return pl.pallas_call(
        functools.partial(_fakequant_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((gpb, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((gpb, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_in, d_out), w.dtype),
        interpret=True,
    )(w, gamma, beta, bits2)


@functools.lru_cache(maxsize=None)
def make_fakequant(group: int, block_rows: int | None = None, block_n: int | None = None):
    """Build a differentiable fakequant(w, gamma, beta, bits) for a given
    group size: Pallas forward, STE backward (VJP of the jnp reference)."""

    @jax.custom_vjp
    def fakequant(w, gamma, beta, bits):
        return fakequant_pallas(
            w, gamma, beta, bits, group=group, block_rows=block_rows, block_n=block_n
        )

    def _fwd(w, gamma, beta, bits):
        return fakequant(w, gamma, beta, bits), (w, gamma, beta, bits)

    def _bwd(res, ct):
        w, gamma, beta, bits = res
        _, vjp = jax.vjp(
            lambda w_, g_, b_: ref.fakequant_ref(w_, g_, b_, bits, group), w, gamma, beta
        )
        dw, dg, db = vjp(ct)
        return dw, dg, db, jnp.zeros_like(bits)

    fakequant.defvjp(_fwd, _bwd)
    return fakequant
