"""L2 step-function tests: each AOT-able step behaves as its contract says
(losses drop, optimizer states thread, manifest flattening is stable)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import steps
from compile.aot import flatten_with_names

from .test_model import init_params, init_qparams, toks

CFG = M.SIZES["tiny"]


def zeros_like_tree(specs):
    return {k: jnp.zeros(v.shape, v.dtype) for k, v in specs.items()}


def scalars(**kw):
    return {k: jnp.float32(v) for k, v in kw.items()}


def test_pretrain_step_reduces_loss():
    fn, arg_specs = steps.build_pretrain_step(CFG)
    params = init_params(CFG, scale=0.02)
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    t = toks(CFG)
    mask = jnp.ones_like(t, dtype=jnp.float32)
    jfn = jax.jit(fn)
    losses = []
    state = {"params": params, "m": m, "v": v}
    for i in range(8):
        out = jfn({**state, "tokens": t, "mask": mask,
                   **scalars(t=float(i + 1), lr=3e-3, wd=0.0)})
        state = {"params": out["params"], "m": out["m"], "v": out["v"]}
        losses.append(float(out["loss"]))
    assert losses[-1] < losses[0], losses


def test_lw_calib_step_reduces_activation_error():
    d_in, d_out, r, g = 256, 256, 16, 64
    fn, arg_specs = steps.build_lw_calib_step(CFG, d_in, d_out, r, g)
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (d_in, d_out)) * 0.1
    n_tok = CFG.calib_batch * CFG.seq_len
    x = jax.random.normal(jax.random.PRNGKey(1), (n_tok, d_in))
    qp = {
        "gamma": jnp.full((d_in // g, d_out), 4.0),
        "beta": jnp.full((d_in // g, d_out), 4.0),
        "lora_a": jax.random.normal(jax.random.PRNGKey(2), (d_in, r)) * 0.01,
        "lora_b": jnp.zeros((d_out, r)),
    }
    m = {k: jnp.zeros_like(v) for k, v in qp.items()}
    v = {k: jnp.zeros_like(x_) for k, x_ in qp.items()}
    jfn = jax.jit(fn)
    losses = []
    for i in range(25):
        out = jfn({
            "w": w, "qp": qp, "m": m, "v": v, "x": x, "xq": x,
            **scalars(t=float(i + 1), lr_ab=5e-3, lr_gb=5e-3,
                      wd_ab=0.0, wd_gb=0.0, bits=2.0, scale=1.0),
        })
        qp, m, v = out["qp"], out["m"], out["v"]
        losses.append(float(out["loss"]))
    assert losses[-1] < 0.85 * losses[0], (losses[0], losses[-1])


def test_lw_calib_with_zero_ab_lr_is_omniquant():
    """lr_ab=0 must leave A,B untouched (OmniQuant-lite mode)."""
    d_in, d_out, r, g = 256, 256, 16, 64
    fn, _ = steps.build_lw_calib_step(CFG, d_in, d_out, r, g)
    w = jax.random.normal(jax.random.PRNGKey(0), (d_in, d_out)) * 0.1
    n_tok = CFG.calib_batch * CFG.seq_len
    x = jax.random.normal(jax.random.PRNGKey(1), (n_tok, d_in))
    a0 = jax.random.normal(jax.random.PRNGKey(2), (d_in, r)) * 0.01
    qp = {
        "gamma": jnp.full((d_in // g, d_out), 4.0),
        "beta": jnp.full((d_in // g, d_out), 4.0),
        "lora_a": a0,
        "lora_b": jnp.zeros((d_out, r)),
    }
    m = {k: jnp.zeros_like(v) for k, v in qp.items()}
    v = {k: jnp.zeros_like(x_) for k, x_ in qp.items()}
    out = jax.jit(fn)({
        "w": w, "qp": qp, "m": m, "v": v, "x": x, "xq": x,
        **scalars(t=1.0, lr_ab=0.0, lr_gb=5e-3, wd_ab=0.0, wd_gb=0.0,
                  bits=2.0, scale=1.0),
    })
    np.testing.assert_allclose(out["qp"]["lora_a"], a0, atol=0)
    np.testing.assert_allclose(out["qp"]["lora_b"], 0.0, atol=0)
    assert float(jnp.max(jnp.abs(out["qp"]["gamma"] - 4.0))) > 0


def test_bw_calib_step_reduces_block_error():
    fn, _ = steps.build_bw_calib_step(CFG, rank=16, group=64)
    params = init_params(CFG, scale=0.05)
    bp = {k.split(".", 2)[2]: v for k, v in params.items() if k.startswith("blocks.0.")}
    qspecs = M.block_qparam_specs(CFG, 16, 64)
    key = jax.random.PRNGKey(5)
    bqp = {}
    for name, shape in qspecs.items():
        key, sub = jax.random.split(key)
        leaf = name.rsplit(".", 1)[1]
        bqp[name] = {
            "gamma": jnp.full(shape, 4.0), "beta": jnp.full(shape, 4.0),
            "lora_a": jax.random.normal(sub, shape) * 0.01,
            "lora_b": jnp.zeros(shape),
        }[leaf]
    train_keys = [k for k in qspecs]
    m = {k: jnp.zeros(qspecs[k]) for k in train_keys}
    v = {k: jnp.zeros(qspecs[k]) for k in train_keys}
    x = jax.random.normal(jax.random.PRNGKey(6),
                          (CFG.calib_batch, CFG.seq_len, CFG.d_model)) * 0.5
    jfn = jax.jit(fn)
    losses = []
    for i in range(15):
        out = jfn({
            "bp": bp, "bqp": bqp, "m": m, "v": v, "x": x, "xq": x,
            **scalars(t=float(i + 1), lr_ab=2e-3, lr_gb=2e-3,
                      wd_ab=0.0, wd_gb=0.0, bits=2.0, scale=1.0),
        })
        bqp, m, v = out["bqp"], out["m"], out["v"]
        losses.append(float(out["loss"]))
    assert losses[-1] < 0.85 * losses[0], (losses[0], losses[-1])


def test_finetune_step_only_updates_adapters():
    fn, _ = steps.build_finetune_step(CFG, rank=16, group=64)
    params = init_params(CFG, scale=0.02)
    qp = init_qparams(CFG, 16, 64)
    train_keys = [k for k in qp if k.rsplit(".", 1)[1] in ("lora_a", "lora_b")]
    m = {k: jnp.zeros_like(qp[k]) for k in train_keys}
    v = {k: jnp.zeros_like(qp[k]) for k in train_keys}
    t = toks(CFG)
    mask = jnp.ones_like(t, dtype=jnp.float32)
    out = jax.jit(fn)({
        "params": params, "qparams": qp, "m": m, "v": v, "tokens": t, "mask": mask,
        **scalars(t=1.0, lr=1e-3, wd=0.0, bits=4.0, scale=1.0,
                  lr_attn_mul=1.0, lr_ffn_mul=1.0),
    })
    # gamma/beta frozen during finetuning
    for k in qp:
        leaf = k.rsplit(".", 1)[1]
        if leaf in ("gamma", "beta"):
            np.testing.assert_allclose(out["qparams"][k], qp[k], atol=0)
    # adapters moved
    moved = sum(
        float(jnp.max(jnp.abs(out["qparams"][k] - qp[k]))) > 0 for k in train_keys
    )
    assert moved >= len(train_keys) // 2
    assert float(out["loss"]) > 0


def test_finetune_step_position_freezing():
    """lr_attn_mul=0 must freeze attention adapters (Table 1 machinery)."""
    fn, _ = steps.build_finetune_step(CFG, rank=16, group=64)
    params = init_params(CFG, scale=0.02)
    qp = init_qparams(CFG, 16, 64)
    train_keys = [k for k in qp if k.rsplit(".", 1)[1] in ("lora_a", "lora_b")]
    m = {k: jnp.zeros_like(qp[k]) for k in train_keys}
    v = {k: jnp.zeros_like(qp[k]) for k in train_keys}
    t = toks(CFG)
    out = jax.jit(fn)({
        "params": params, "qparams": qp, "m": m, "v": v, "tokens": t,
        "mask": jnp.ones_like(t, dtype=jnp.float32),
        **scalars(t=1.0, lr=1e-3, wd=0.0, bits=4.0, scale=1.0,
                  lr_attn_mul=0.0, lr_ffn_mul=1.0),
    })
    for k in train_keys:
        lin = k.split(".")[2]
        delta = float(jnp.max(jnp.abs(out["qparams"][k] - qp[k])))
        if lin in ("wq", "wk", "wv", "wo"):
            assert delta == 0.0, k
    ffn_moved = [
        k for k in train_keys
        if k.split(".")[2] in ("wgate", "wup", "wdown")
        and float(jnp.max(jnp.abs(out["qparams"][k] - qp[k]))) > 0
    ]
    assert ffn_moved


def test_block_inputs_fp_q_consistency():
    """At bits=16 / open clip / B=0 the q-stream must track the fp stream."""
    fn_fp, _ = steps.build_block_inputs_fp(CFG)
    fn_q, _ = steps.build_block_inputs_q(CFG, rank=16, group=64)
    params = init_params(CFG, scale=0.05)
    bp = {k.split(".", 2)[2]: v for k, v in params.items() if k.startswith("blocks.0.")}
    qspecs = M.block_qparam_specs(CFG, 16, 64)
    bqp = {}
    for name, shape in qspecs.items():
        leaf = name.rsplit(".", 1)[1]
        bqp[name] = {
            "gamma": jnp.full(shape, 20.0), "beta": jnp.full(shape, 20.0),
            "lora_a": jnp.zeros(shape), "lora_b": jnp.zeros(shape),
        }[leaf]
    x = jax.random.normal(jax.random.PRNGKey(9),
                          (CFG.calib_batch, CFG.seq_len, CFG.d_model)) * 0.5
    out_fp = jax.jit(fn_fp)({"bp": bp, "x": x})
    out_q = jax.jit(fn_q)({"bp": bp, "bqp": bqp, "x": x,
                           **scalars(bits=16.0, scale=1.0)})
    for k in ("out", "attn_in", "o_in", "ffn_in", "down_in"):
        np.testing.assert_allclose(out_q[k], out_fp[k], atol=1e-3, rtol=1e-4)


def test_manifest_flattening_is_sorted_and_stable():
    _, arg_specs = steps.build_lw_calib_step(CFG, 256, 256, 16, 64)
    flat = flatten_with_names(arg_specs)
    names = [n for n, _ in flat]
    assert names == sorted(names)
    _, arg_specs2 = steps.build_lw_calib_step(CFG, 256, 256, 16, 64)
    assert names == [n for n, _ in flatten_with_names(arg_specs2)]
