"""L2 model correctness: shapes, invariants, fp-vs-quant consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


CFG = M.SIZES["tiny"]


def init_params(cfg, seed=0, scale=0.05):
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in M.param_specs(cfg).items():
        key, sub = jax.random.split(key)
        if name.endswith("_norm"):
            params[name] = jnp.ones(shape)
        else:
            params[name] = jax.random.normal(sub, shape) * scale
    return params


def init_qparams(cfg, rank, group, adapter="lora", seed=1, a_scale=0.01):
    key = jax.random.PRNGKey(seed)
    qp = {}
    for name, shape in M.qparam_specs(cfg, rank, group, adapter).items():
        key, sub = jax.random.split(key)
        leaf = name.rsplit(".", 1)[1]
        if leaf in ("gamma", "beta"):
            qp[name] = jnp.full(shape, 4.0)
        elif leaf == "lora_a":
            qp[name] = jax.random.normal(sub, shape) * a_scale
        elif leaf == "lora_b":
            qp[name] = jnp.zeros(shape)
        elif leaf == "mag":
            qp[name] = jnp.ones(shape)
    return qp


def toks(cfg, seed=7):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (cfg.batch, cfg.seq_len), 0, cfg.vocab
    ).astype(jnp.int32)


def test_fp_forward_shape():
    params = init_params(CFG)
    logits = M.model_forward(CFG, params, toks(CFG))
    assert logits.shape == (CFG.batch * CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality():
    """Changing a future token must not change past logits."""
    params = init_params(CFG)
    t1 = toks(CFG)
    t2 = t1.at[:, -1].set((t1[:, -1] + 1) % CFG.vocab)
    l1 = M.model_forward(CFG, params, t1).reshape(CFG.batch, CFG.seq_len, -1)
    l2 = M.model_forward(CFG, params, t2).reshape(CFG.batch, CFG.seq_len, -1)
    np.testing.assert_allclose(l1[:, :-1], l2[:, :-1], atol=1e-5)
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) > 1e-6


def test_quant_forward_high_bits_matches_fp():
    """bits=16 with open clipping and B=0 must reproduce the fp model."""
    params = init_params(CFG)
    qp = init_qparams(CFG, rank=16, group=64)
    for k in list(qp):
        if k.endswith("gamma") or k.endswith("beta"):
            qp[k] = jnp.full_like(qp[k], 20.0)
    t = toks(CFG)
    l_fp = M.model_forward(CFG, params, t)
    l_q = M.model_forward(
        CFG, params, t, mode="lora", qparams=qp,
        bits=jnp.float32(16.0), scale=jnp.float32(1.0), group=64,
    )
    np.testing.assert_allclose(l_q, l_fp, atol=0.05)


def test_quant_forward_2bit_differs():
    params = init_params(CFG)
    qp = init_qparams(CFG, rank=16, group=64)
    t = toks(CFG)
    l_fp = M.model_forward(CFG, params, t)
    l_q = M.model_forward(
        CFG, params, t, mode="lora", qparams=qp,
        bits=jnp.float32(2.0), scale=jnp.float32(1.0), group=64,
    )
    assert float(jnp.max(jnp.abs(l_q - l_fp))) > 0.01


def test_dora_forward_shape():
    params = init_params(CFG)
    qp = init_qparams(CFG, rank=16, group=64, adapter="dora")
    l_q = M.model_forward(
        CFG, params, toks(CFG), mode="dora", qparams=qp,
        bits=jnp.float32(2.0), scale=jnp.float32(1.0), group=64,
    )
    assert l_q.shape == (CFG.batch * CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(l_q)))


def test_block_collect_activations():
    params = init_params(CFG)
    bp = {k.split(".", 2)[2]: v for k, v in params.items() if k.startswith("blocks.0.")}
    x = jax.random.normal(jax.random.PRNGKey(3), (2, CFG.seq_len, CFG.d_model))
    linear = M.make_linear("fp", None, None, None, 64)
    out, acts = M.block_forward(CFG, bp, x, linear, collect=True)
    assert out.shape == x.shape
    assert acts["attn_in"].shape == x.shape
    assert acts["down_in"].shape == (2, CFG.seq_len, CFG.d_ffn)
    # residual identity: out = x + attn_out + ffn_out
    np.testing.assert_allclose(
        out, x + acts["attn_out"] + acts["ffn_out"], atol=1e-5
    )


def test_loss_masking():
    params = init_params(CFG)
    t = toks(CFG)
    logits = M.model_forward(CFG, params, t)
    full = M.next_token_loss(CFG, logits, t, jnp.ones_like(t, dtype=jnp.float32))
    half_mask = jnp.concatenate(
        [jnp.zeros((CFG.batch, CFG.seq_len // 2)),
         jnp.ones((CFG.batch, CFG.seq_len // 2))], axis=1
    )
    half = M.next_token_loss(CFG, logits, t, half_mask)
    assert full != half
    zero = M.next_token_loss(CFG, logits, t, jnp.zeros_like(half_mask))
    assert float(zero) == 0.0


def test_loss_is_log_vocab_at_init():
    """Random near-zero init ⇒ uniform logits ⇒ loss ≈ ln(V)."""
    params = init_params(CFG, scale=0.001)
    t = toks(CFG)
    logits = M.model_forward(CFG, params, t)
    loss = M.next_token_loss(CFG, logits, t, jnp.ones_like(t, dtype=jnp.float32))
    assert abs(float(loss) - float(jnp.log(CFG.vocab))) < 0.1


@pytest.mark.parametrize("size", ["tiny", "small"])
def test_param_specs_complete(size):
    cfg = M.SIZES[size]
    specs = M.param_specs(cfg)
    assert len(specs) == 3 + cfg.n_layers * (2 + len(M.LINEAR_NAMES))
    n_params = sum(int(np.prod(s)) for s in specs.values())
    if size == "tiny":
        assert 3e6 < n_params < 5e6, n_params
    else:
        assert 25e6 < n_params < 35e6, n_params


def test_base_is_about_100m():
    cfg = M.SIZES["base"]
    n = sum(int(np.prod(s)) for s in M.param_specs(cfg).values())
    assert 85e6 < n < 115e6, n


def test_qparam_specs_group_divisibility():
    for size in ("tiny", "small", "base"):
        cfg = M.SIZES[size]
        for g in (64, 128):
            specs = M.qparam_specs(cfg, 16, g)
            for name, shape in specs.items():
                if name.endswith("gamma"):
                    lin = name.split(".")[2]
                    d_in, d_out = cfg.linear_shape(lin)
                    assert shape == (d_in // g, d_out)
