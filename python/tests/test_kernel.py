"""L1 kernel correctness: Pallas vs pure-jnp oracle (kernels/ref.py).

Hypothesis sweeps shapes/bit-widths/group sizes; assert_allclose against
ref.  This is the CORE correctness signal for the quantization math that
everything downstream (calibration, finetuning, Rust packing) relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    fakequant_pallas,
    make_fakequant,
    make_qlora_matmul,
    qlora_matmul_pallas,
    ref,
)


def rand(key, *shape, scale=0.1):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# fakequant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2.0, 3.0, 4.0, 8.0])
@pytest.mark.parametrize("shape,group", [((128, 64), 64), ((256, 128), 64), ((128, 32), 32)])
def test_fakequant_matches_ref(bits, shape, group):
    w = rand(0, *shape)
    gpc = shape[0] // group
    gamma = jnp.full((gpc, shape[1]), 4.0)
    beta = jnp.full((gpc, shape[1]), 4.0)
    b = jnp.float32(bits)
    out_p = fakequant_pallas(w, gamma, beta, b, group=group)
    out_r = ref.fakequant_ref(w, gamma, beta, b, group)
    np.testing.assert_allclose(out_p, out_r, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    groups_per_col=st.integers(1, 4),
    group=st.sampled_from([16, 32, 64]),
    d_out=st.sampled_from([16, 48, 128]),
    bits=st.sampled_from([2.0, 3.0, 4.0]),
    seed=st.integers(0, 2**16),
    gb_val=st.floats(-2.0, 6.0),
)
def test_fakequant_hypothesis(groups_per_col, group, d_out, bits, seed, gb_val):
    d_in = groups_per_col * group
    w = rand(seed, d_in, d_out, scale=0.5)
    gamma = jnp.full((groups_per_col, d_out), gb_val)
    beta = jnp.full((groups_per_col, d_out), gb_val)
    b = jnp.float32(bits)
    out_p = fakequant_pallas(w, gamma, beta, b, group=group)
    out_r = ref.fakequant_ref(w, gamma, beta, b, group)
    np.testing.assert_allclose(out_p, out_r, atol=1e-5)


def test_fakequant_levels_are_discrete():
    """Q/s + z must land on at most 2^b integer levels per group."""
    w = rand(1, 64, 8, scale=1.0)
    gamma = jnp.full((1, 8), 4.0)
    beta = jnp.full((1, 8), 4.0)
    q = fakequant_pallas(w, gamma, beta, jnp.float32(2.0), group=64)
    for col in range(8):
        levels = np.unique(np.round(np.asarray(q[:, col]), 6))
        assert len(levels) <= 4, f"2-bit column has {len(levels)} levels"


def test_fakequant_bits16_near_identity():
    w = rand(2, 128, 64)
    gamma = jnp.full((2, 64), 20.0)
    beta = jnp.full((2, 64), 20.0)
    q = fakequant_pallas(w, gamma, beta, jnp.float32(16.0), group=64)
    np.testing.assert_allclose(q, w, atol=1e-4)


def test_fakequant_error_decreases_with_bits():
    w = rand(3, 256, 64, scale=0.3)
    gamma = jnp.full((4, 64), 4.0)
    beta = jnp.full((4, 64), 4.0)
    errs = []
    for bits in (2.0, 3.0, 4.0, 8.0):
        q = fakequant_pallas(w, gamma, beta, jnp.float32(bits), group=64)
        errs.append(float(jnp.linalg.norm(q - w)))
    assert errs == sorted(errs, reverse=True), errs


def test_fakequant_grad_matches_ref():
    w = rand(4, 128, 32)
    gamma = jnp.full((2, 32), 4.0)
    beta = jnp.full((2, 32), 4.0)
    bits = jnp.float32(2.0)
    fq = make_fakequant(64)
    tgt = rand(5, 128, 32)

    def loss_p(w_, g_, b_):
        return jnp.mean((fq(w_, g_, b_, bits) - tgt) ** 2)

    def loss_r(w_, g_, b_):
        return jnp.mean((ref.fakequant_ref(w_, g_, b_, bits, 64) - tgt) ** 2)

    gp = jax.grad(loss_p, argnums=(0, 1, 2))(w, gamma, beta)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(w, gamma, beta)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, atol=1e-7)


def test_fakequant_gamma_grad_direction():
    """Widening gamma from a shrunken clip range must reduce clipping error
    for a weight matrix with outliers -> gradient should be negative (push
    gamma up) when the range is too narrow."""
    w = rand(6, 64, 16, scale=1.0)
    gamma = jnp.full((1, 16), -2.0)  # sigmoid ~= 0.12: heavy clipping
    beta = jnp.full((1, 16), -2.0)
    bits = jnp.float32(4.0)
    fq = make_fakequant(64)

    def loss(g_, b_):
        return jnp.mean((fq(w, g_, b_, bits) - w) ** 2)

    dg, db = jax.grad(loss, argnums=(0, 1))(gamma, beta)
    # loss should decrease as clip range expands
    assert float(jnp.mean(dg)) < 0.0
    assert float(jnp.mean(db)) < 0.0


# ---------------------------------------------------------------------------
# fused qlora matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [2.0, 4.0])
@pytest.mark.parametrize("m,d_in,d_out,r,group", [
    (16, 128, 64, 8, 64), (8, 256, 128, 4, 64), (32, 64, 64, 16, 32),
])
def test_qlora_matmul_matches_ref(bits, m, d_in, d_out, r, group):
    x = rand(10, m, d_in, scale=1.0)
    w = rand(11, d_in, d_out)
    gpc = d_in // group
    gamma = jnp.full((gpc, d_out), 4.0)
    beta = jnp.full((gpc, d_out), 4.0)
    a = rand(12, d_in, r)
    b = rand(13, d_out, r)
    bb = jnp.float32(bits)
    sc = jnp.float32(1.0)
    out_p = qlora_matmul_pallas(x, w, gamma, beta, a, b, bb, sc, group=group)
    out_r = ref.qlora_matmul_ref(x, w, gamma, beta, a, b, bb, sc, group)
    np.testing.assert_allclose(out_p, out_r, atol=1e-4)


def test_qlora_matmul_tiled_grid():
    """Multi-cell grid must agree with single-cell (tiling correctness)."""
    x = rand(20, 64, 128, scale=1.0)
    w = rand(21, 128, 128)
    gamma = jnp.full((2, 128), 4.0)
    beta = jnp.full((2, 128), 4.0)
    a = rand(22, 128, 8)
    b = rand(23, 128, 8)
    bb = jnp.float32(3.0)
    sc = jnp.float32(1.0)
    full = qlora_matmul_pallas(x, w, gamma, beta, a, b, bb, sc, group=64)
    tiled = qlora_matmul_pallas(
        x, w, gamma, beta, a, b, bb, sc, group=64, block_m=32, block_n=64
    )
    np.testing.assert_allclose(tiled, full, atol=1e-5)


def test_fakequant_tiled_grid():
    w = rand(24, 256, 128)
    gamma = jnp.full((4, 128), 4.0)
    beta = jnp.full((4, 128), 4.0)
    bb = jnp.float32(2.0)
    full = fakequant_pallas(w, gamma, beta, bb, group=64)
    tiled = fakequant_pallas(w, gamma, beta, bb, group=64, block_rows=128, block_n=64)
    np.testing.assert_allclose(tiled, full, atol=1e-6)


def test_qlora_zero_b_is_plain_quant():
    """With B=0 the fused kernel must equal x @ fakequant(W) (QLoRA init)."""
    x = rand(30, 16, 128, scale=1.0)
    w = rand(31, 128, 64)
    gamma = jnp.full((2, 64), 4.0)
    beta = jnp.full((2, 64), 4.0)
    a = rand(32, 128, 8)
    b = jnp.zeros((64, 8))
    bb = jnp.float32(2.0)
    out = qlora_matmul_pallas(x, w, gamma, beta, a, b, bb, jnp.float32(1.0), group=64)
    q = fakequant_pallas(w, gamma, beta, bb, group=64)
    np.testing.assert_allclose(out, x @ q, atol=1e-5)


def test_qlora_grad_matches_ref():
    x = rand(40, 32, 128, scale=1.0)
    w = rand(41, 128, 64)
    gamma = jnp.full((2, 64), 4.0)
    beta = jnp.full((2, 64), 4.0)
    a = rand(42, 128, 8)
    b = rand(43, 64, 8)
    bits = jnp.float32(2.0)
    sc = jnp.float32(1.0)
    qm = make_qlora_matmul(64)
    y = x @ w

    def loss_p(a_, b_, g_, be_):
        return jnp.mean((qm(x, w, g_, be_, a_, b_, bits, sc) - y) ** 2)

    def loss_r(a_, b_, g_, be_):
        return jnp.mean((ref.qlora_matmul_ref(x, w, g_, be_, a_, b_, bits, sc, 64) - y) ** 2)

    gp = jax.grad(loss_p, argnums=(0, 1, 2, 3))(a, b, gamma, beta)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(a, b, gamma, beta)
    for p_, r_ in zip(gp, gr):
        np.testing.assert_allclose(p_, r_, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([4, 16, 64]),
    r=st.sampled_from([1, 4, 16]),
    bits=st.sampled_from([2.0, 3.0, 4.0]),
    seed=st.integers(0, 2**16),
)
def test_qlora_hypothesis(m, r, bits, seed):
    d_in, d_out, group = 128, 64, 64
    x = rand(seed, m, d_in, scale=1.0)
    w = rand(seed + 1, d_in, d_out)
    gamma = jnp.full((2, d_out), 4.0)
    beta = jnp.full((2, d_out), 4.0)
    a = rand(seed + 2, d_in, r)
    b = rand(seed + 3, d_out, r)
    bb = jnp.float32(bits)
    sc = jnp.float32(2.0)
    out_p = qlora_matmul_pallas(x, w, gamma, beta, a, b, bb, sc, group=group)
    out_r = ref.qlora_matmul_ref(x, w, gamma, beta, a, b, bb, sc, group)
    np.testing.assert_allclose(out_p, out_r, atol=1e-4)


# ---------------------------------------------------------------------------
# Calibration dynamics: one lw-style optimization actually reduces Eq. (4)
# ---------------------------------------------------------------------------

def test_apiq_objective_decreases():
    """Mini ApiQ-lw run: activation error must drop vs the QLoRA init.

    This is the paper's core claim at unit scale (Fig. 4 / Table 2 shape).
    """
    d_in, d_out, r, group = 128, 64, 8, 64
    x = rand(50, 256, d_in, scale=1.0)
    w = rand(51, d_in, d_out, scale=0.2)
    gamma = jnp.full((2, d_out), 4.0)
    beta = jnp.full((2, d_out), 4.0)
    a = rand(52, d_in, r, scale=0.01)
    b = jnp.zeros((d_out, r))
    bits = jnp.float32(2.0)
    sc = jnp.float32(1.0)
    qm = make_qlora_matmul(group)
    y = x @ w

    def loss_fn(params):
        a_, b_, g_, be_ = params
        yq = qm(x, w, g_, be_, a_, b_, bits, sc)
        return jnp.mean((y - yq) ** 2)

    params = (a, b, gamma, beta)
    loss0 = float(loss_fn(params))
    # Adam, as in Algorithm 1 (plain SGD stalls at B=0 where dA = 0).
    grad_fn = jax.jit(jax.grad(loss_fn))
    m = tuple(jnp.zeros_like(p) for p in params)
    v = tuple(jnp.zeros_like(p) for p in params)
    lr = 5e-3
    for t in range(1, 61):
        g = grad_fn(params)
        m = tuple(0.9 * mi + 0.1 * gi for mi, gi in zip(m, g))
        v = tuple(0.999 * vi + 0.001 * gi * gi for vi, gi in zip(v, g))
        params = tuple(
            p - lr * (mi / (1 - 0.9**t)) / (jnp.sqrt(vi / (1 - 0.999**t)) + 1e-8)
            for p, mi, vi in zip(params, m, v)
        )
    loss1 = float(loss_fn(params))
    assert loss1 < 0.8 * loss0, (loss0, loss1)
